//! Engine abstraction: the four backends a batch can be dispatched to.
//!
//! * [`NativeEngine`]-backed — the real multicore path (production).
//! * Sim-backed — Algorithm 1 over a simulated Table-1 GPU (capacity
//!   limits and the traffic ledger apply; used by experiments and for
//!   failure-injection tests via tiny simulated devices).
//! * PJRT-backed — the AOT JAX/Pallas pipeline via the XLA CPU client
//!   (fixed shapes from `artifacts/manifest.json`).
//! * Sharded — Algorithm 1 per device across a [`DevicePool`] with a
//!   deterministic cross-device combine; accepts jobs beyond any single
//!   device's memory ceiling.

use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
use crate::algos::sharded::{ShardedSort, ShardedSortParams};
use crate::config::{EngineKind, ServiceConfig};
use crate::error::{Error, Result};
use crate::exec::NativeEngine;
use crate::runtime::PjrtRuntime;
use crate::sim::{DeviceLease, DevicePool, GpuModel, GpuSim, GpuSpec};
use crate::util::pool;
use crate::Key;

/// A sort backend able to process a batch of independent jobs.
///
/// One engine instance is owned by exactly one scheduler worker thread —
/// it is *constructed on that thread* (see `SortService::start`) — so
/// implementations may hold non-`Send`/non-`Sync` state (the PJRT
/// client's `Rc` internals in particular).
pub trait SortEngine {
    /// Which configuration enum this engine realizes.
    fn kind(&self) -> EngineKind;

    /// Sort every job of the batch; one result per job, order preserved.
    /// Jobs fail individually (e.g. a simulated OOM) without failing the
    /// batch.
    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>>;

    /// Largest single job this engine accepts, if bounded.
    fn max_job_keys(&self) -> Option<usize> {
        None
    }
}

/// Native multicore backend: jobs in a batch run concurrently on the
/// virtual-SM pool, each internally parallel.
pub struct NativeSortEngine {
    engine: NativeEngine,
}

impl NativeSortEngine {
    /// Build from config.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Ok(NativeSortEngine {
            engine: NativeEngine::new(cfg.native)?,
        })
    }

    /// Access the inner engine (reports, tests).
    pub fn inner(&self) -> &NativeEngine {
        &self.engine
    }
}

impl SortEngine for NativeSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        // Small jobs run in parallel with each other (dynamic queue —
        // job sizes vary); the engine parallelizes internally for large
        // ones, which land in their own batches.
        let engine = &self.engine;
        pool::parallel_map(jobs, engine.workers(), |mut keys| {
            engine.sort(&mut keys);
            Ok(keys)
        })
    }
}

/// Simulated-GPU backend: Algorithm 1 with full traffic accounting and
/// the device's memory ceiling.
pub struct SimSortEngine {
    spec: GpuSpec,
    sorter: BucketSort,
}

impl SimSortEngine {
    /// Build from config.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Ok(SimSortEngine {
            spec: cfg.device.spec(),
            sorter: BucketSort::try_new(cfg.sort)?,
        })
    }

    /// Build directly from a spec and params (tests, experiments).
    pub fn from_parts(spec: GpuSpec, params: BucketSortParams) -> Result<Self> {
        Ok(SimSortEngine {
            spec,
            sorter: BucketSort::try_new(params)?,
        })
    }
}

impl SortEngine for SimSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sim
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|mut keys| {
                let mut sim = GpuSim::new(self.spec.clone());
                self.sorter.sort(&mut keys, &mut sim)?;
                Ok(keys)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.spec.max_sortable_keys())
    }
}

/// Sharded multi-device backend: Algorithm 1 per simulated device over
/// a capacity-weighted partition, plus the deterministic cross-device
/// combine of [`crate::algos::sharded`].
pub struct ShardedSortEngine {
    models: Vec<GpuModel>,
    sorter: ShardedSort,
    /// Held when the devices were checked out of a shared
    /// [`crate::sim::DeviceRegistry`] (multi-worker schedulers); the
    /// devices return to the registry when the engine drops.
    _lease: Option<DeviceLease>,
}

impl ShardedSortEngine {
    /// Build from config (`cfg.devices` + `cfg.sort`).
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Self::from_parts(
            cfg.devices.clone(),
            ShardedSortParams {
                sort: cfg.sort,
                ..Default::default()
            },
        )
    }

    /// Build directly from a device list and parameters (tests,
    /// experiments).
    pub fn from_parts(models: Vec<GpuModel>, params: ShardedSortParams) -> Result<Self> {
        if models.is_empty() {
            return Err(Error::Config(
                "sharded engine needs at least one device".into(),
            ));
        }
        Ok(ShardedSortEngine {
            models,
            sorter: ShardedSort::try_new(params)?,
            _lease: None,
        })
    }

    /// Build over devices leased from a shared registry — the
    /// multi-worker path, where each scheduler worker holds a disjoint
    /// subset of the configured pool.
    pub fn with_lease(lease: DeviceLease, params: ShardedSortParams) -> Result<Self> {
        let mut engine = Self::from_parts(lease.models().to_vec(), params)?;
        engine._lease = Some(lease);
        Ok(engine)
    }

    /// The device models backing each job's pool.
    pub fn models(&self) -> &[GpuModel] {
        &self.models
    }
}

impl SortEngine for ShardedSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sharded
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|mut keys| {
                let mut pool = DevicePool::new(&self.models)?;
                self.sorter.sort(&mut keys, &mut pool)?;
                Ok(keys)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(
            self.models
                .iter()
                .map(|m| m.spec().max_sortable_keys())
                .sum(),
        )
    }
}

/// PJRT backend: the AOT-compiled fixed-shape pipeline.
pub struct PjrtSortEngine {
    runtime: PjrtRuntime,
}

impl PjrtSortEngine {
    /// Load artifacts and warm the executable cache.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let mut runtime = PjrtRuntime::new(cfg.artifacts_dir.clone())?;
        runtime.warm_up()?;
        Ok(PjrtSortEngine { runtime })
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }
}

impl SortEngine for PjrtSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pjrt
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|keys| self.runtime.sort(&keys).map(|(sorted, _cap)| sorted))
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.runtime.manifest().max_sort_capacity())
    }
}

/// Device-paced simulated engine: output computed on the host with a
/// fast comparison sort, *occupancy* priced by the analytic cost model
/// of the simulated device — the worker stays busy for the device's
/// estimated wall time, like a real accelerator-attached engine waiting
/// on its stream. This is what makes multi-worker throughput studies
/// honest on a small host: each worker stands in for one GPU, and
/// aggregate throughput scales with simulated devices, not host cores.
///
/// Jobs beyond the device's memory ceiling fail with the same OOM as
/// [`SimSortEngine`] (the pricing pass performs the capacity
/// accounting).
pub struct PacedSimEngine {
    spec: GpuSpec,
    sorter: BucketSort,
    time_scale: f64,
}

impl PacedSimEngine {
    /// Build over one simulated device. `time_scale` stretches or
    /// shrinks the priced device time (1.0 = Table 1 calibration; 0
    /// disables pacing entirely — pure correctness tests).
    pub fn new(model: GpuModel, params: BucketSortParams, time_scale: f64) -> Result<Self> {
        if !time_scale.is_finite() || time_scale < 0.0 {
            return Err(Error::InvalidParams(
                "time_scale must be finite and non-negative".into(),
            ));
        }
        Ok(PacedSimEngine {
            spec: model.spec(),
            sorter: BucketSort::try_new(params)?,
            time_scale,
        })
    }
}

impl SortEngine for PacedSimEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sim
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        let started = std::time::Instant::now();
        let mut device_ms = 0.0;
        let results: Vec<Result<Vec<Key>>> = jobs
            .into_iter()
            .map(|mut keys| {
                let mut sim = GpuSim::new(self.spec.clone());
                // Analytic pricing enforces the memory ceiling and
                // yields the deterministic device estimate; the data
                // work itself is a plain host sort.
                self.sorter.sort_analytic(keys.len(), &mut sim)?;
                device_ms += sim.estimated_ms();
                keys.sort_unstable();
                Ok(keys)
            })
            .collect();
        // Hold the worker for the rest of the simulated device time —
        // a batch is one stream, so job estimates add up.
        let budget_ms = device_ms * self.time_scale;
        let host_ms = started.elapsed().as_secs_f64() * 1e3;
        if budget_ms > host_ms {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (budget_ms - host_ms) / 1e3,
            ));
        }
        results
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.spec.max_sortable_keys())
    }
}

/// Build the engine selected by `cfg.engine`.
pub fn build_engine(cfg: &ServiceConfig) -> Result<Box<dyn SortEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeSortEngine::new(cfg)?)),
        EngineKind::Sim => Ok(Box::new(SimSortEngine::new(cfg)?)),
        EngineKind::Pjrt => Ok(Box::new(PjrtSortEngine::new(cfg)?)),
        EngineKind::Sharded => Ok(Box::new(ShardedSortEngine::new(cfg)?)),
    }
}

/// Build the engine for scheduler worker `worker` of `cfg.workers`.
///
/// Identical to [`build_engine`] except for the sharded engine in a
/// multi-worker scheduler: there each worker checks its share of
/// `cfg.devices` out of the shared `registry`, so concurrent workers
/// hold disjoint device subsets (no oversubscription).
pub fn build_worker_engine(
    cfg: &ServiceConfig,
    worker: usize,
    registry: Option<&crate::sim::DeviceRegistry>,
) -> Result<Box<dyn SortEngine>> {
    match (cfg.engine, registry) {
        (EngineKind::Sharded, Some(registry)) => {
            let share =
                crate::sim::DeviceRegistry::share_for(worker, cfg.workers, registry.total());
            let lease = registry.checkout(share)?;
            Ok(Box::new(ShardedSortEngine::with_lease(
                lease,
                ShardedSortParams {
                    sort: cfg.sort,
                    ..Default::default()
                },
            )?))
        }
        _ => build_engine(cfg),
    }
}

/// Shared post-condition check used by the service's verify mode.
pub fn verify_outcome(input: &[Key], output: &[Key]) -> Result<()> {
    if !crate::is_sorted_permutation(input, output) {
        return Err(Error::Coordinator(
            "verification failed: output is not a sorted permutation of the input".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;

    #[test]
    fn native_engine_sorts_batches() {
        let cfg = ServiceConfig::default();
        let mut e = NativeSortEngine::new(&cfg).unwrap();
        let jobs = vec![
            vec![3u32, 1, 2],
            vec![],
            (0..10_000u32).rev().collect::<Vec<_>>(),
        ];
        let results = e.sort_batch(jobs.clone());
        assert_eq!(results.len(), 3);
        for (inp, res) in jobs.iter().zip(&results) {
            let out = res.as_ref().unwrap();
            assert!(crate::is_sorted_permutation(inp, out));
        }
        assert_eq!(e.kind(), EngineKind::Native);
    }

    #[test]
    fn sim_engine_respects_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sim,
            device: GpuModel::Gtx260,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = SimSortEngine::new(&cfg).unwrap();
        assert!(e.max_job_keys().unwrap() > 64 << 20);
        let results = e.sort_batch(vec![vec![5u32, 4, 3, 2, 1]]);
        assert_eq!(results[0].as_ref().unwrap(), &vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn sim_engine_oom_fails_job_not_batch() {
        // A too-large job fails with OOM while its batch-mates succeed.
        let mut e = SimSortEngine::from_parts(
            GpuModel::Gtx260.spec(),
            BucketSortParams { tile: 256, s: 16 },
        )
        .unwrap();
        let big = vec![1u32; 130 << 20 >> 2]; // ~130M keys? keep it analytic-light: use capacity check instead
        drop(big);
        // Use the analytic capacity: a job over max_sortable_keys OOMs.
        // (Executing a >64M-key sort for real is too slow for a unit
        // test, so fabricate with a tiny device instead.)
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20, // 1 MB
            ..GpuModel::Gtx260.spec()
        };
        let mut e_tiny =
            SimSortEngine::from_parts(tiny, BucketSortParams { tile: 256, s: 16 }).unwrap();
        let jobs = vec![vec![2u32, 1], vec![0u32; 200_000]];
        let results = e_tiny.sort_batch(jobs);
        assert!(results[0].is_ok());
        let err = results[1].as_ref().unwrap_err();
        assert!(err.is_oom(), "{err}");
        let _ = e.sort_batch(vec![]);
    }

    #[test]
    fn verify_catches_corruption() {
        assert!(verify_outcome(&[2, 1], &[1, 2]).is_ok());
        assert!(verify_outcome(&[2, 1], &[1, 3]).is_err());
        assert!(verify_outcome(&[2, 1], &[2, 1]).is_err());
    }

    #[test]
    fn sharded_engine_sorts_and_advertises_pool_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = ShardedSortEngine::new(&cfg).unwrap();
        assert_eq!(e.kind(), EngineKind::Sharded);
        assert_eq!(e.models().len(), 4);
        // Pool capacity exceeds every single device's ceiling.
        assert!(e.max_job_keys().unwrap() > 512 << 20);
        let jobs: Vec<Vec<Key>> = vec![
            (0..50_000u32).rev().collect(),
            vec![],
            (0..10_000u32).map(|x| x.wrapping_mul(2654435761)).collect(),
        ];
        let results = e.sort_batch(jobs.clone());
        for (inp, res) in jobs.iter().zip(&results) {
            assert!(crate::is_sorted_permutation(inp, res.as_ref().unwrap()));
        }
        // Empty device lists are rejected up front.
        assert!(ShardedSortEngine::from_parts(vec![], ShardedSortParams::default()).is_err());
    }

    #[test]
    fn paced_sim_engine_sorts_and_respects_capacity() {
        // time_scale 0: no pacing sleep, pure correctness check.
        let mut e =
            PacedSimEngine::new(GpuModel::Gtx285_2G, BucketSortParams { tile: 256, s: 16 }, 0.0)
                .unwrap();
        assert_eq!(e.kind(), EngineKind::Sim);
        assert_eq!(
            e.max_job_keys(),
            Some(GpuModel::Gtx285_2G.spec().max_sortable_keys())
        );
        let jobs: Vec<Vec<Key>> = vec![
            (0..10_000u32).rev().collect(),
            vec![],
            vec![7, 7, 3, 3, 1],
        ];
        let results = e.sort_batch(jobs.clone());
        for (inp, res) in jobs.iter().zip(&results) {
            assert!(crate::is_sorted_permutation(inp, res.as_ref().unwrap()));
        }
        // Over-ceiling jobs OOM exactly like the executing sim engine.
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let mut paced_tiny = PacedSimEngine {
            spec: tiny,
            sorter: BucketSort::try_new(BucketSortParams { tile: 256, s: 16 }).unwrap(),
            time_scale: 0.0,
        };
        let results = paced_tiny.sort_batch(vec![vec![0u32; 300_000], vec![2, 1]]);
        assert!(results[0].as_ref().unwrap_err().is_oom());
        assert_eq!(results[1].as_ref().unwrap(), &vec![1, 2]);
        // Bad scales rejected.
        assert!(PacedSimEngine::new(GpuModel::Gtx260, BucketSortParams::default(), -1.0).is_err());
        assert!(
            PacedSimEngine::new(GpuModel::Gtx260, BucketSortParams::default(), f64::NAN).is_err()
        );
    }

    #[test]
    fn worker_engines_lease_disjoint_device_shares() {
        use crate::sim::DeviceRegistry;
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            workers: 2,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let registry = DeviceRegistry::new(cfg.devices.clone());
        let e0 = build_worker_engine(&cfg, 0, Some(&registry)).unwrap();
        let e1 = build_worker_engine(&cfg, 1, Some(&registry)).unwrap();
        assert_eq!(e0.kind(), EngineKind::Sharded);
        assert_eq!(e1.kind(), EngineKind::Sharded);
        // 4 devices over 2 workers: both leases hold 2, none left over.
        assert_eq!(registry.available(), 0);
        // A third worker would oversubscribe and is refused.
        assert!(build_worker_engine(&cfg, 2, Some(&registry)).is_err());
        // Dropping an engine returns its devices.
        drop(e0);
        assert_eq!(registry.available(), 2);
        drop(e1);
        assert_eq!(registry.available(), 4);
        // Without a registry the plain config path is used.
        let plain = build_worker_engine(&cfg, 0, None).unwrap();
        assert_eq!(plain.kind(), EngineKind::Sharded);
    }

    #[test]
    fn build_engine_dispatches() {
        let native = build_engine(&ServiceConfig::default()).unwrap();
        assert_eq!(native.kind(), EngineKind::Native);
        let sim = build_engine(&ServiceConfig {
            engine: EngineKind::Sim,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sim.kind(), EngineKind::Sim);
        let sharded = build_engine(&ServiceConfig {
            engine: EngineKind::Sharded,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sharded.kind(), EngineKind::Sharded);
        // PJRT without artifacts → manifest error.
        let pjrt = build_engine(&ServiceConfig {
            engine: EngineKind::Pjrt,
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        });
        assert!(pjrt.is_err());
    }
}

//! Engine abstraction: the four backends a batch can be dispatched to.
//!
//! * [`NativeEngine`]-backed — the real multicore path (production).
//! * Sim-backed — Algorithm 1 over a simulated Table-1 GPU (capacity
//!   limits and the traffic ledger apply; used by experiments and for
//!   failure-injection tests via tiny simulated devices).
//! * PJRT-backed — the AOT JAX/Pallas pipeline via the XLA CPU client
//!   (fixed shapes from `artifacts/manifest.json`).
//! * Sharded — Algorithm 1 per device across a [`DevicePool`] with a
//!   deterministic cross-device combine; accepts jobs beyond any single
//!   device's memory ceiling.

use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
use crate::algos::sharded::{ShardedSort, ShardedSortParams};
use crate::config::{EngineKind, ServiceConfig};
use crate::error::{Error, Result};
use crate::exec::NativeEngine;
use crate::runtime::PjrtRuntime;
use crate::sim::{DevicePool, GpuModel, GpuSim, GpuSpec};
use crate::util::pool;
use crate::Key;

/// A sort backend able to process a batch of independent jobs.
///
/// One engine instance is owned by the service's single engine thread —
/// it is *constructed on that thread* (see `SortService::start`) — so
/// implementations may hold non-`Send`/non-`Sync` state (the PJRT
/// client's `Rc` internals in particular).
pub trait SortEngine {
    /// Which configuration enum this engine realizes.
    fn kind(&self) -> EngineKind;

    /// Sort every job of the batch; one result per job, order preserved.
    /// Jobs fail individually (e.g. a simulated OOM) without failing the
    /// batch.
    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>>;

    /// Largest single job this engine accepts, if bounded.
    fn max_job_keys(&self) -> Option<usize> {
        None
    }
}

/// Native multicore backend: jobs in a batch run concurrently on the
/// virtual-SM pool, each internally parallel.
pub struct NativeSortEngine {
    engine: NativeEngine,
}

impl NativeSortEngine {
    /// Build from config.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Ok(NativeSortEngine {
            engine: NativeEngine::new(cfg.native)?,
        })
    }

    /// Access the inner engine (reports, tests).
    pub fn inner(&self) -> &NativeEngine {
        &self.engine
    }
}

impl SortEngine for NativeSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        // Small jobs run in parallel with each other (dynamic queue —
        // job sizes vary); the engine parallelizes internally for large
        // ones, which land in their own batches.
        let engine = &self.engine;
        pool::parallel_map(jobs, engine.workers(), |mut keys| {
            engine.sort(&mut keys);
            Ok(keys)
        })
    }
}

/// Simulated-GPU backend: Algorithm 1 with full traffic accounting and
/// the device's memory ceiling.
pub struct SimSortEngine {
    spec: GpuSpec,
    sorter: BucketSort,
}

impl SimSortEngine {
    /// Build from config.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Ok(SimSortEngine {
            spec: cfg.device.spec(),
            sorter: BucketSort::try_new(cfg.sort)?,
        })
    }

    /// Build directly from a spec and params (tests, experiments).
    pub fn from_parts(spec: GpuSpec, params: BucketSortParams) -> Result<Self> {
        Ok(SimSortEngine {
            spec,
            sorter: BucketSort::try_new(params)?,
        })
    }
}

impl SortEngine for SimSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sim
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|mut keys| {
                let mut sim = GpuSim::new(self.spec.clone());
                self.sorter.sort(&mut keys, &mut sim)?;
                Ok(keys)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.spec.max_sortable_keys())
    }
}

/// Sharded multi-device backend: Algorithm 1 per simulated device over
/// a capacity-weighted partition, plus the deterministic cross-device
/// combine of [`crate::algos::sharded`].
pub struct ShardedSortEngine {
    models: Vec<GpuModel>,
    sorter: ShardedSort,
}

impl ShardedSortEngine {
    /// Build from config (`cfg.devices` + `cfg.sort`).
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        Self::from_parts(
            cfg.devices.clone(),
            ShardedSortParams {
                sort: cfg.sort,
                ..Default::default()
            },
        )
    }

    /// Build directly from a device list and parameters (tests,
    /// experiments).
    pub fn from_parts(models: Vec<GpuModel>, params: ShardedSortParams) -> Result<Self> {
        if models.is_empty() {
            return Err(Error::Config(
                "sharded engine needs at least one device".into(),
            ));
        }
        Ok(ShardedSortEngine {
            models,
            sorter: ShardedSort::try_new(params)?,
        })
    }

    /// The device models backing each job's pool.
    pub fn models(&self) -> &[GpuModel] {
        &self.models
    }
}

impl SortEngine for ShardedSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sharded
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|mut keys| {
                let mut pool = DevicePool::new(&self.models)?;
                self.sorter.sort(&mut keys, &mut pool)?;
                Ok(keys)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(
            self.models
                .iter()
                .map(|m| m.spec().max_sortable_keys())
                .sum(),
        )
    }
}

/// PJRT backend: the AOT-compiled fixed-shape pipeline.
pub struct PjrtSortEngine {
    runtime: PjrtRuntime,
}

impl PjrtSortEngine {
    /// Load artifacts and warm the executable cache.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let mut runtime = PjrtRuntime::new(cfg.artifacts_dir.clone())?;
        runtime.warm_up()?;
        Ok(PjrtSortEngine { runtime })
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }
}

impl SortEngine for PjrtSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pjrt
    }

    fn sort_batch(&mut self, jobs: Vec<Vec<Key>>) -> Vec<Result<Vec<Key>>> {
        jobs.into_iter()
            .map(|keys| self.runtime.sort(&keys).map(|(sorted, _cap)| sorted))
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.runtime.manifest().max_sort_capacity())
    }
}

/// Build the engine selected by `cfg.engine`.
pub fn build_engine(cfg: &ServiceConfig) -> Result<Box<dyn SortEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeSortEngine::new(cfg)?)),
        EngineKind::Sim => Ok(Box::new(SimSortEngine::new(cfg)?)),
        EngineKind::Pjrt => Ok(Box::new(PjrtSortEngine::new(cfg)?)),
        EngineKind::Sharded => Ok(Box::new(ShardedSortEngine::new(cfg)?)),
    }
}

/// Shared post-condition check used by the service's verify mode.
pub fn verify_outcome(input: &[Key], output: &[Key]) -> Result<()> {
    if !crate::is_sorted_permutation(input, output) {
        return Err(Error::Coordinator(
            "verification failed: output is not a sorted permutation of the input".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;

    #[test]
    fn native_engine_sorts_batches() {
        let cfg = ServiceConfig::default();
        let mut e = NativeSortEngine::new(&cfg).unwrap();
        let jobs = vec![
            vec![3u32, 1, 2],
            vec![],
            (0..10_000u32).rev().collect::<Vec<_>>(),
        ];
        let results = e.sort_batch(jobs.clone());
        assert_eq!(results.len(), 3);
        for (inp, res) in jobs.iter().zip(&results) {
            let out = res.as_ref().unwrap();
            assert!(crate::is_sorted_permutation(inp, out));
        }
        assert_eq!(e.kind(), EngineKind::Native);
    }

    #[test]
    fn sim_engine_respects_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sim,
            device: GpuModel::Gtx260,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = SimSortEngine::new(&cfg).unwrap();
        assert!(e.max_job_keys().unwrap() > 64 << 20);
        let results = e.sort_batch(vec![vec![5u32, 4, 3, 2, 1]]);
        assert_eq!(results[0].as_ref().unwrap(), &vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn sim_engine_oom_fails_job_not_batch() {
        // A too-large job fails with OOM while its batch-mates succeed.
        let mut e = SimSortEngine::from_parts(
            GpuModel::Gtx260.spec(),
            BucketSortParams { tile: 256, s: 16 },
        )
        .unwrap();
        let big = vec![1u32; 130 << 20 >> 2]; // ~130M keys? keep it analytic-light: use capacity check instead
        drop(big);
        // Use the analytic capacity: a job over max_sortable_keys OOMs.
        // (Executing a >64M-key sort for real is too slow for a unit
        // test, so fabricate with a tiny device instead.)
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20, // 1 MB
            ..GpuModel::Gtx260.spec()
        };
        let mut e_tiny =
            SimSortEngine::from_parts(tiny, BucketSortParams { tile: 256, s: 16 }).unwrap();
        let jobs = vec![vec![2u32, 1], vec![0u32; 200_000]];
        let results = e_tiny.sort_batch(jobs);
        assert!(results[0].is_ok());
        let err = results[1].as_ref().unwrap_err();
        assert!(err.is_oom(), "{err}");
        let _ = e.sort_batch(vec![]);
    }

    #[test]
    fn verify_catches_corruption() {
        assert!(verify_outcome(&[2, 1], &[1, 2]).is_ok());
        assert!(verify_outcome(&[2, 1], &[1, 3]).is_err());
        assert!(verify_outcome(&[2, 1], &[2, 1]).is_err());
    }

    #[test]
    fn sharded_engine_sorts_and_advertises_pool_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = ShardedSortEngine::new(&cfg).unwrap();
        assert_eq!(e.kind(), EngineKind::Sharded);
        assert_eq!(e.models().len(), 4);
        // Pool capacity exceeds every single device's ceiling.
        assert!(e.max_job_keys().unwrap() > 512 << 20);
        let jobs: Vec<Vec<Key>> = vec![
            (0..50_000u32).rev().collect(),
            vec![],
            (0..10_000u32).map(|x| x.wrapping_mul(2654435761)).collect(),
        ];
        let results = e.sort_batch(jobs.clone());
        for (inp, res) in jobs.iter().zip(&results) {
            assert!(crate::is_sorted_permutation(inp, res.as_ref().unwrap()));
        }
        // Empty device lists are rejected up front.
        assert!(ShardedSortEngine::from_parts(vec![], ShardedSortParams::default()).is_err());
    }

    #[test]
    fn build_engine_dispatches() {
        let native = build_engine(&ServiceConfig::default()).unwrap();
        assert_eq!(native.kind(), EngineKind::Native);
        let sim = build_engine(&ServiceConfig {
            engine: EngineKind::Sim,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sim.kind(), EngineKind::Sim);
        let sharded = build_engine(&ServiceConfig {
            engine: EngineKind::Sharded,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sharded.kind(), EngineKind::Sharded);
        // PJRT without artifacts → manifest error.
        let pjrt = build_engine(&ServiceConfig {
            engine: EngineKind::Pjrt,
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        });
        assert!(pjrt.is_err());
    }
}

//! The sort service: intake thread + dynamic batching + a pool of
//! engine workers, on std channels and condvars (the build is offline —
//! no async runtime).
//!
//! Topology (one intake, N workers — one worker per engine instance;
//! the paper's system is a single GPU, so a worker is the software twin
//! of one device):
//!
//! ```text
//!  SortClient ──mpsc──▶ intake thread ──(Batch)──▶ Scheduler queue
//!      ▲                   │ Batcher                 │ condvar
//!      │                   ◀─ SlotFreed ──┐   ┌──────┴──────┐
//!      │                                  │   ▼             ▼
//!      │                                  │ worker 0 …  worker N−1
//!      └────── per-request oneshot ◀──────┴── outcomes ─────┘
//! ```
//!
//! * The **intake thread** owns the [`Batcher`]: admits requests (or
//!   rejects with backpressure) and fires a batch when a budget fills or
//!   the oldest request's wait expires (`recv_timeout` against the
//!   batcher's deadline).
//! * The **scheduler** ([`super::scheduler`]) fans batches out to N
//!   worker threads, each owning its own (possibly non-`Sync`) engine.
//!   Batches complete out of order across workers; every response is
//!   still byte-identical to the single-worker service (see the
//!   scheduler docs for the determinism argument).
//! * Responses travel back through per-request channels, so callers
//!   blocked on different requests never contend.
//! * There is **no sleep-polling anywhere in the path**: a full
//!   scheduler parks the intake on its message channel, and workers
//!   wake it with a `SlotFreed` message when capacity frees.

use super::batcher::Batcher;
use super::engine::{self, SortEngine};
use super::request::{Batch, PendingRequest, SortRequest, SortResponse};
use super::scheduler::{DispatchError, Scheduler, WorkerEngineFactory};
use crate::config::ServiceConfig;
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::sim::fault::FaultInjector;
use crate::sim::{DeviceRegistry, FaultPlan};
use crate::util::sync::{self as sync, lock_unpoisoned, Arc, AtomicU64, Mutex, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::time::Instant;

enum ClientMsg {
    Submit(PendingRequest),
    /// A worker finished a batch: scheduler capacity freed, re-poll.
    SlotFreed,
    /// Every `SortClient` clone dropped: drain and stop.
    ClientsGone,
    Shutdown(mpsc::Sender<()>),
}

/// Owns the intake sender; the last clone's drop tells the intake loop
/// every client is gone (workers also hold senders for `SlotFreed`, so
/// channel disconnection can no longer signal it) and then **joins the
/// intake thread** — it used to be spawned detached and leaked past
/// shutdown, leaving a background thread (and its scheduler, batcher
/// and metrics references) alive after the service was gone.
#[derive(Debug)]
struct ClientCore {
    tx: mpsc::Sender<ClientMsg>,
    intake: Option<sync::thread::JoinHandle<()>>,
}

impl Drop for ClientCore {
    fn drop(&mut self) {
        let _ = self.tx.send(ClientMsg::ClientsGone);
        // The intake loop exits on ClientsGone (or has already exited
        // after an explicit shutdown); joining here guarantees no
        // service thread outlives the last client handle. Drop has
        // exclusive access (the Arc's last-owner drop runs once), so a
        // plain Option suffices.
        if let Some(handle) = self.intake.take() {
            let _ = handle.join();
        }
    }
}

/// Handle to a running sort service. Cloneable; [`SortClient::shutdown`]
/// (or dropping every clone) stops the service after draining.
#[derive(Clone, Debug)]
pub struct SortClient {
    core: Arc<ClientCore>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    /// The service's fault injector, when a plan is armed. Exposed so
    /// chaos tests (and the net tier) can share one injector — every
    /// injection, wherever probed, lands in the same
    /// `fault_injected_*` totals.
    faults: Option<Arc<FaultInjector>>,
}

impl SortClient {
    /// Submit a request and block until its response arrives.
    pub fn sort(&self, request: SortRequest) -> Result<SortResponse> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("request dropped during shutdown".into()))?
    }

    /// Submit without blocking; returns the response channel.
    pub fn submit(&self, request: SortRequest) -> Result<Receiver<Result<SortResponse>>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = PendingRequest {
            id,
            request,
            admitted_at: Instant::now(),
            respond_to: tx,
        };
        self.core
            .tx
            .send(ClientMsg::Submit(req))
            .map_err(|_| Error::Coordinator("service stopped".into()))?;
        Ok(rx)
    }

    /// Convenience: sort a plain `u32` key vector (the classic path).
    pub fn sort_keys(&self, keys: Vec<crate::Key>) -> Result<Vec<crate::Key>> {
        self.sort(SortRequest::new(keys))?
            .keys
            .into_u32()
            .ok_or_else(|| {
                Error::Coordinator("u32 request returned a different key type".into())
            })
    }

    /// Snapshot of the service metrics.
    /// The service's live fault injector, when `cfg.fault_plan` armed
    /// one. Chaos tests hand this to
    /// [`crate::net::ClientOptions::faults`] so client-side probes
    /// (`socket_cut`, `frame_corrupt`) draw from the same seeded rule
    /// set — and count into the same `fault_injected_*` totals — as
    /// the device- and scheduler-level points.
    pub fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        self.faults.clone()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful drain through **any** handle: complete queued work, stop
    /// the intake and every worker, return the final metrics. Signalled
    /// end to end — the intake acks only after the scheduler has joined
    /// its workers, so the returned snapshot is complete (no polling
    /// quantization).
    ///
    /// Unlike [`SortClient::shutdown`] this does not consume the handle,
    /// so a transport front end (e.g. the TCP server, which shares the
    /// service with in-process callers) can drain while other clones
    /// are still alive. It is idempotent: once the intake has exited,
    /// further calls return the final snapshot immediately. Requests
    /// submitted through surviving clones afterwards fail with the same
    /// typed "service stopped" error a socket-backed client observes as
    /// a `shutdown` error frame.
    pub fn drain(&self) -> MetricsSnapshot {
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.core.tx.send(ClientMsg::Shutdown(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
        self.metrics.snapshot()
    }

    /// Graceful shutdown: [`SortClient::drain`] plus consuming this
    /// handle (the classic in-process call shape).
    pub fn shutdown(self) -> MetricsSnapshot {
        self.drain()
    }
}

/// Service constructor namespace.
pub struct SortService;

impl SortService {
    /// Start a service with `cfg.workers` engines selected by `cfg`.
    ///
    /// Engines are constructed **on their worker threads** — PJRT state
    /// is not `Send`, and a GPU context likewise belongs to the thread
    /// that drives it. Construction failures are reported back here. A
    /// multi-worker sharded service checks each worker's devices out of
    /// one shared [`DeviceRegistry`], so concurrent workers hold
    /// disjoint slices of `cfg.devices`.
    ///
    /// A configured `cfg.fault_plan` compiles into **one**
    /// [`FaultInjector`] shared by the scheduler and every worker
    /// engine, so rule counters and `fault_injected_*` metrics span the
    /// whole service.
    pub fn start(cfg: ServiceConfig) -> Result<SortClient> {
        let faults = FaultPlan::resolve(&cfg.fault_plan)?.map(|plan| plan.injector());
        let registry = (cfg.engine == crate::config::EngineKind::Sharded && cfg.workers > 1)
            .then(|| DeviceRegistry::new(cfg.devices.clone()));
        let engine_faults = faults.clone();
        Self::start_inner(
            cfg,
            move |cfg: &ServiceConfig, worker: usize| {
                engine::build_worker_engine(cfg, worker, registry.as_ref(), engine_faults.clone())
            },
            faults,
        )
    }

    /// Start with an explicit engine (tests inject mocks/tiny devices).
    /// Single-engine by construction, so it requires `cfg.workers == 1`.
    pub fn start_with_engine<E: SortEngine + Send + 'static>(
        cfg: ServiceConfig,
        engine: E,
    ) -> Result<SortClient> {
        Self::start_with_factory(cfg, move |_| Ok(Box::new(engine) as Box<dyn SortEngine>))
    }

    /// Start with a one-shot engine factory that runs on the worker
    /// thread. Single-engine by construction (`FnOnce`), so it requires
    /// `cfg.workers == 1`; use
    /// [`SortService::start_with_worker_factory`] for a pool.
    pub fn start_with_factory(
        cfg: ServiceConfig,
        factory: impl FnOnce(&ServiceConfig) -> Result<Box<dyn SortEngine>> + Send + 'static,
    ) -> Result<SortClient> {
        if cfg.workers != 1 {
            return Err(Error::Config(format!(
                "a single injected engine serves exactly 1 worker (workers = {})",
                cfg.workers
            )));
        }
        let factory = Mutex::new(Some(factory));
        Self::start_with_worker_factory(cfg, move |cfg: &ServiceConfig, _worker: usize| {
            let f = lock_unpoisoned(&factory).take().ok_or_else(|| {
                Error::Coordinator("single-worker engine factory invoked twice".into())
            })?;
            f(cfg)
        })
    }

    /// Start with a per-worker engine factory: called once per worker,
    /// on that worker's thread, with the worker index. A configured
    /// `cfg.fault_plan` still arms the *scheduler-level* fault points
    /// (worker panic, slow device, deadlines/retries); injected engines
    /// that want device-level faults must wire the injector themselves.
    pub fn start_with_worker_factory<F>(cfg: ServiceConfig, factory: F) -> Result<SortClient>
    where
        F: Fn(&ServiceConfig, usize) -> Result<Box<dyn SortEngine>> + Send + Sync + 'static,
    {
        let faults = FaultPlan::resolve(&cfg.fault_plan)?.map(|plan| plan.injector());
        Self::start_inner(cfg, factory, faults)
    }

    fn start_inner<F>(
        cfg: ServiceConfig,
        factory: F,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<SortClient>
    where
        F: Fn(&ServiceConfig, usize) -> Result<Box<dyn SortEngine>> + Send + Sync + 'static,
    {
        cfg.validate()?;
        let metrics = Arc::new(Metrics::new());
        let (client_tx, client_rx) = mpsc::channel::<ClientMsg>();

        let slot_tx = client_tx.clone();
        let scheduler = Scheduler::start(
            &cfg,
            Arc::new(factory) as Arc<WorkerEngineFactory>,
            metrics.clone(),
            Box::new(move || {
                let _ = slot_tx.send(ClientMsg::SlotFreed);
            }),
            faults.clone(),
        )?;

        let intake_metrics = metrics.clone();
        let batcher = Batcher::new(cfg.batch);
        let intake_faults = faults.clone();
        let intake = sync::thread::spawn_named("gbs-intake".into(), move || {
            intake_loop(client_rx, scheduler, batcher, intake_metrics, intake_faults)
        });

        Ok(SortClient {
            core: Arc::new(ClientCore {
                tx: client_tx,
                intake: Some(intake),
            }),
            metrics,
            next_id: Arc::new(AtomicU64::new(1)),
            faults,
        })
    }
}

fn intake_loop(
    client_rx: Receiver<ClientMsg>,
    scheduler: Scheduler,
    mut batcher: Batcher,
    metrics: Arc<Metrics>,
    faults: Option<Arc<FaultInjector>>,
) {
    let mut shutdown_ack: Option<mpsc::Sender<()>> = None;
    loop {
        // Fire ready batches, without blocking on a full scheduler: a
        // blocked intake could not run admission control, and queued
        // requests would silently bypass backpressure.
        //
        // §Perf: while the pool has spare capacity there is nothing to
        // gain from waiting out the batching window — company can only
        // arrive while every worker is busy anyway — so drain
        // immediately. This removes the full max_wait_ms from
        // unloaded-path latency.
        let mut scheduler_full = false;
        let mut pool_dead = false;
        loop {
            let batch = if scheduler.has_spare_capacity() {
                batcher.drain()
            } else {
                batcher.poll(Instant::now())
            };
            let Some(batch) = batch else { break };
            match scheduler.try_dispatch(batch) {
                Ok(()) => metrics.incr("batches_dispatched", 1),
                Err(DispatchError::Full(batch)) => {
                    batcher.restore_front(batch);
                    scheduler_full = true;
                    break;
                }
                Err(DispatchError::Dead(batch)) => {
                    fail_batch(batch, "engine workers stopped");
                    pool_dead = true;
                    break;
                }
            }
        }
        if pool_dead {
            break;
        }

        let msg = if scheduler_full {
            // Every dispatch slot is taken, so the batcher deadline
            // cannot matter: nothing changes until a worker frees a
            // slot (SlotFreed) or a client speaks — both arrive here.
            client_rx.recv().ok()
        } else {
            match batcher.next_deadline() {
                Some(deadline) => {
                    let now = Instant::now();
                    if deadline <= now {
                        continue; // a batch is ready right now: re-poll
                    }
                    match client_rx.recv_timeout(deadline - now) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => None,
                    }
                }
                None => client_rx.recv().ok(),
            }
        };

        match msg {
            Some(ClientMsg::Submit(req)) => {
                metrics.incr("requests_received", 1);
                metrics.incr("keys_received", req.len() as u64);
                if let Err(e) = req.request.validate() {
                    // Malformed requests (payload/key length mismatch)
                    // are rejected before admission.
                    metrics.incr("requests_rejected", 1);
                    let _ = req.respond_to.send(Err(e));
                    continue;
                }
                if req.is_empty() {
                    // Zero-key jobs complete immediately (no engine
                    // trip), echoing the request's key type and
                    // (empty) payload.
                    let outcome = SortResponse {
                        id: req.id,
                        keys: req.request.keys,
                        payload: req.request.payload,
                        tag: req.request.tag,
                        engine: crate::config::EngineKind::Native,
                        worker: 0,
                        batch_size: 0,
                        queue_ms: 0.0,
                        service_ms: 0.0,
                    };
                    let _ = req.respond_to.send(Ok(outcome));
                    continue;
                }
                if let Err((e, rejected)) = batcher.admit(req) {
                    metrics.incr("requests_rejected", 1);
                    let _ = rejected.respond_to.send(Err(e));
                }
            }
            Some(ClientMsg::SlotFreed) => continue,
            Some(ClientMsg::Shutdown(ack)) => {
                shutdown_ack = Some(ack);
                break;
            }
            Some(ClientMsg::ClientsGone) | None => break,
        }
    }
    // Drain whatever is still queued — blocking dispatch is safe now
    // (admission is closed) and guarantees every admitted request
    // reaches a worker, unless the pool died (then the requests are
    // failed rather than stranded).
    while let Some(batch) = batcher.drain() {
        let batch_len = batch.len() as u64;
        match scheduler.dispatch_blocking(batch) {
            Ok(()) => {
                metrics.incr("batches_dispatched", 1);
                metrics.incr("batched_requests", batch_len);
            }
            Err(batch) => fail_batch(batch, "engine workers stopped"),
        }
    }
    // Stops the workers once the queue is empty and joins them;
    // outcomes are still delivered through per-request channels.
    scheduler.shutdown();
    // Final export of the injector's per-point totals, so the shutdown
    // snapshot also covers faults injected after the last batch (net
    // tier probes share this injector).
    if let Some(inj) = &faults {
        for (point, n) in inj.injected() {
            metrics.record_max(&format!("fault_injected_{point}"), n);
        }
    }
    if let Some(ack) = shutdown_ack {
        let _ = ack.send(());
    }
}

/// Reject every request of a batch that can no longer be served.
fn fail_batch(batch: Batch, why: &str) {
    for req in batch.requests {
        let _ = req
            .respond_to
            .send(Err(Error::Coordinator(why.to_string())));
    }
}

//! The sort service: intake thread + dynamic batching + a dedicated
//! engine thread, on std channels (the build is offline — no async
//! runtime; a synchronous leader is also truer to the paper's
//! single-device execution model).
//!
//! Topology (one leader, one engine — the paper's system is a single
//! GPU; scale-out is per-process):
//!
//! ```text
//!  SortClient ──mpsc──▶ intake thread ──(Batch)──▶ engine thread
//!      ▲                   │ Batcher                  │ SortEngine
//!      └──── per-request oneshot ◀── outcomes ────────┘
//! ```
//!
//! * The **intake thread** owns the [`Batcher`]: admits requests (or
//!   rejects with backpressure) and fires a batch when a budget fills or
//!   the oldest request's wait expires (`recv_timeout` against the
//!   batcher's deadline).
//! * The **engine thread** owns the (possibly non-`Sync`) engine — the
//!   PJRT client in particular — and executes batches serially, like a
//!   GPU stream. Python is never involved: the PJRT engine runs
//!   AOT-compiled artifacts.
//! * Responses travel back through per-request channels, so callers
//!   blocked on different requests never contend.

use super::batcher::Batcher;
use super::engine::{self, SortEngine};
use super::request::{Batch, PendingRequest, SortJob, SortOutcome};
use crate::config::ServiceConfig;
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

enum ClientMsg {
    Submit(PendingRequest),
    Shutdown(mpsc::Sender<()>),
}

/// Handle to a running sort service. Cloneable; [`SortClient::shutdown`]
/// (or dropping every clone) stops the service after draining.
#[derive(Clone, Debug)]
pub struct SortClient {
    tx: mpsc::Sender<ClientMsg>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
}

impl SortClient {
    /// Submit a job and block until its outcome arrives.
    pub fn sort(&self, job: SortJob) -> Result<SortOutcome> {
        let rx = self.submit(job)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("request dropped during shutdown".into()))?
    }

    /// Submit without blocking; returns the response channel.
    pub fn submit(&self, job: SortJob) -> Result<Receiver<Result<SortOutcome>>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = PendingRequest {
            id,
            job,
            admitted_at: Instant::now(),
            respond_to: tx,
        };
        self.tx
            .send(ClientMsg::Submit(req))
            .map_err(|_| Error::Coordinator("service stopped".into()))?;
        Ok(rx)
    }

    /// Convenience: sort a plain key vector.
    pub fn sort_keys(&self, keys: Vec<crate::Key>) -> Result<Vec<crate::Key>> {
        Ok(self.sort(SortJob::new(keys))?.keys)
    }

    /// Snapshot of the service metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: drain queued work, stop both threads, return
    /// the final metrics.
    pub fn shutdown(self) -> MetricsSnapshot {
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.tx.send(ClientMsg::Shutdown(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
        self.metrics.snapshot()
    }
}

/// Service constructor namespace.
pub struct SortService;

impl SortService {
    /// Start a service with the engine selected by `cfg`.
    ///
    /// The engine is constructed **on the engine thread** — PJRT state
    /// is not `Send`, and a GPU context likewise belongs to the thread
    /// that drives it. Construction failures are reported back here.
    pub fn start(cfg: ServiceConfig) -> Result<SortClient> {
        Self::start_with_factory(cfg, engine::build_engine)
    }

    /// Start with an explicit engine (tests inject mocks/tiny devices).
    pub fn start_with_engine<E: SortEngine + Send + 'static>(
        cfg: ServiceConfig,
        engine: E,
    ) -> Result<SortClient> {
        Self::start_with_factory(cfg, move |_| Ok(Box::new(engine) as Box<dyn SortEngine>))
    }

    /// Start with an engine factory that runs on the engine thread.
    pub fn start_with_factory(
        cfg: ServiceConfig,
        factory: impl FnOnce(&ServiceConfig) -> Result<Box<dyn SortEngine>> + Send + 'static,
    ) -> Result<SortClient> {
        cfg.validate()?;
        let metrics = Arc::new(Metrics::new());
        let (client_tx, client_rx) = mpsc::channel::<ClientMsg>();
        // Bounded: at most 2 batches in flight keeps queue-delay
        // accounting honest (like a depth-2 GPU stream).
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Batch>(2);

        let engine_metrics = metrics.clone();
        let verify = cfg.verify;
        let engine_cfg = cfg.clone();
        let in_flight = Arc::new(AtomicU64::new(0));
        let engine_in_flight = in_flight.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("gbs-engine".into())
            .spawn(move || match factory(&engine_cfg) {
                Ok(engine) => {
                    let _ = ready_tx.send(Ok(()));
                    engine_loop(engine, batch_rx, engine_metrics, verify, engine_in_flight);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Coordinator("engine thread died during construction".into()))??;

        let intake_metrics = metrics.clone();
        let batcher = Batcher::new(cfg.batch);
        std::thread::Builder::new()
            .name("gbs-intake".into())
            .spawn(move || intake_loop(client_rx, batch_tx, batcher, intake_metrics, in_flight))
            .map_err(|e| Error::Coordinator(format!("spawn intake thread: {e}")))?;

        Ok(SortClient {
            tx: client_tx,
            metrics,
            next_id: Arc::new(AtomicU64::new(1)),
        })
    }
}

fn intake_loop(
    client_rx: Receiver<ClientMsg>,
    batch_tx: SyncSender<Batch>,
    mut batcher: Batcher,
    metrics: Arc<Metrics>,
    in_flight: Arc<AtomicU64>,
) {
    let mut shutdown_ack: Option<mpsc::Sender<()>> = None;
    'main: loop {
        // Fire ready batches, without blocking on a full engine channel:
        // a blocked intake could not run admission control, and queued
        // requests would silently bypass backpressure.
        //
        // §Perf: when the engine is idle there is nothing to gain from
        // waiting out the batching window — company can only arrive
        // while the engine is busy anyway — so drain immediately. This
        // removes the full max_wait_ms from unloaded-path latency.
        let mut engine_full = false;
        loop {
            let engine_idle = in_flight.load(Ordering::SeqCst) == 0;
            let batch = if engine_idle {
                batcher.drain()
            } else {
                batcher.poll(Instant::now())
            };
            let Some(batch) = batch else { break };
            in_flight.fetch_add(1, Ordering::SeqCst);
            match batch_tx.try_send(batch) {
                Ok(()) => {
                    metrics.incr("batches_dispatched", 1);
                }
                Err(TrySendError::Full(batch)) => {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    batcher.restore_front(batch);
                    engine_full = true;
                    break;
                }
                Err(TrySendError::Disconnected(_)) => {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    fail_all(&mut batcher, "engine stopped");
                    break 'main;
                }
            }
        }

        let deadline = if engine_full {
            // Engine busy: check back shortly (it has no way to signal
            // a freed slot through the channel).
            Some(Instant::now() + std::time::Duration::from_millis(1))
        } else {
            batcher.next_deadline()
        };
        let msg = match deadline {
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now && !engine_full {
                    continue; // poll again immediately
                }
                let wait = deadline.saturating_duration_since(now).max(std::time::Duration::from_micros(100));
                match client_rx.recv_timeout(wait) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => None,
                }
            }
            None => client_rx.recv().ok(),
        };

        match msg {
            Some(ClientMsg::Submit(req)) => {
                metrics.incr("requests_received", 1);
                metrics.incr("keys_received", req.len() as u64);
                if req.is_empty() {
                    // Zero-key jobs complete immediately (no engine trip).
                    let outcome = SortOutcome {
                        id: req.id,
                        keys: Vec::new(),
                        tag: req.job.tag,
                        engine: crate::config::EngineKind::Native,
                        batch_size: 0,
                        queue_ms: 0.0,
                        service_ms: 0.0,
                    };
                    let _ = req.respond_to.send(Ok(outcome));
                    continue;
                }
                if let Err(e) = batcher.can_admit(req.len()) {
                    metrics.incr("requests_rejected", 1);
                    let _ = req.respond_to.send(Err(e));
                } else {
                    batcher.admit(req).expect("can_admit checked");
                }
            }
            Some(ClientMsg::Shutdown(ack)) => {
                shutdown_ack = Some(ack);
                break;
            }
            None => break, // all clients dropped
        }
    }
    // Drain whatever is still queued.
    while let Some(batch) = batcher.drain() {
        metrics.incr("batches_dispatched", 1);
        metrics.incr("batched_requests", batch.len() as u64);
        if batch_tx.send(batch).is_err() {
            fail_all(&mut batcher, "engine stopped");
            break;
        }
    }
    // Closing batch_tx stops the engine thread once it finishes queued
    // batches; outcomes are still delivered through per-request channels.
    drop(batch_tx);
    if let Some(ack) = shutdown_ack {
        let _ = ack.send(());
    }
}

fn fail_all(batcher: &mut Batcher, why: &str) {
    while let Some(batch) = batcher.drain() {
        for req in batch.requests {
            let _ = req
                .respond_to
                .send(Err(Error::Coordinator(why.to_string())));
        }
    }
}

fn engine_loop(
    mut engine: Box<dyn SortEngine>,
    batch_rx: Receiver<Batch>,
    metrics: Arc<Metrics>,
    verify: bool,
    in_flight: Arc<AtomicU64>,
) {
    while let Ok(batch) = batch_rx.recv() {
        let dispatched = Instant::now();
        let batch_size = batch.len();
        let mut reqs = batch.requests;
        let jobs: Vec<Vec<crate::Key>> = reqs
            .iter_mut()
            .map(|r| std::mem::take(&mut r.job.keys))
            .collect();
        let inputs: Option<Vec<Vec<crate::Key>>> = verify.then(|| jobs.clone());
        let results = engine.sort_batch(jobs);
        debug_assert_eq!(results.len(), batch_size, "engine must answer every job");
        // Mark the engine free *before* delivering outcomes: a caller
        // woken by its response often submits immediately, and must see
        // an idle engine (else it eats a full batching wait — §Perf).
        in_flight.fetch_sub(1, Ordering::SeqCst);
        let service_ms = dispatched.elapsed().as_secs_f64() * 1e3;
        metrics.observe_ms("engine_batch", service_ms);

        for (i, (req, result)) in reqs.into_iter().zip(results).enumerate() {
            let queue_ms = dispatched
                .saturating_duration_since(req.admitted_at)
                .as_secs_f64()
                * 1e3;
            metrics.observe_ms("queue_delay", queue_ms);
            let outcome = result.and_then(|keys| {
                if let Some(inputs) = &inputs {
                    engine::verify_outcome(&inputs[i], &keys)?;
                }
                metrics.incr("requests_completed", 1);
                metrics.incr("keys_sorted", keys.len() as u64);
                Ok(SortOutcome {
                    id: req.id,
                    keys,
                    tag: req.job.tag,
                    engine: engine.kind(),
                    batch_size,
                    queue_ms,
                    service_ms,
                })
            });
            if outcome.is_err() {
                metrics.incr("requests_failed", 1);
            }
            let _ = req.respond_to.send(outcome);
        }
    }
}

//! The multi-worker scheduler: a pool of N engine workers behind one
//! condvar-signalled admission queue.
//!
//! The paper's determinism claim is what makes this safe to build: a
//! batch's outcome depends only on each job's own keys (every engine
//! sorts jobs independently; a sorted key sequence is the unique
//! ordering of its bit-pattern multiset, and key–value jobs sort
//! `Record`s whose tie-breaking index makes the order total), so
//! batches may complete **out of order across workers** while every
//! response stays byte-identical to the single-worker service.
//! Per-request oneshot channels deliver results, so completion order
//! never matters to callers.
//!
//! Design:
//! * one `Mutex<State>` guards the dispatch queue and the per-worker
//!   in-flight table; two condvars signal it (`work`: a batch arrived or
//!   drain started, towards workers; `slots`: a batch finished or left
//!   the queue, towards dispatchers);
//! * each worker owns its engine, built **on the worker thread** by the
//!   factory (PJRT state is not `Send`; a sharded engine leases its own
//!   disjoint device subset);
//! * the queue is bounded at `2 × workers` batches so queue-delay
//!   accounting stays honest (a depth-2 stream per worker, like the
//!   single-engine service's depth-2 channel);
//! * `shutdown` drains: workers finish the queue, then exit; no batch
//!   admitted to the scheduler is ever dropped.
//!
//! After finishing a batch a worker first clears its in-flight slot and
//! *then* delivers the outcomes and fires the `on_slot_free` hook — a
//! caller woken by its response often submits immediately, and must see
//! spare capacity (else it eats a full batching wait).

use super::engine::{self, SortEngine};
use super::queue::{BoundedQueue, PushError};
use super::request::{Batch, JobData, SortResponse};
use crate::config::ServiceConfig;
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::util::sync::{self as sync, Arc};
use std::sync::mpsc;
use std::time::Instant;

/// Builds one worker's engine, on that worker's thread. Called once per
/// worker with the worker index.
pub type WorkerEngineFactory =
    dyn Fn(&ServiceConfig, usize) -> Result<Box<dyn SortEngine>> + Send + Sync;

/// Why a dispatch did not go through. The batch is handed back intact
/// either way.
#[derive(Debug)]
pub enum DispatchError {
    /// The bounded queue is at capacity — re-dispatch after a slot-free
    /// wake-up.
    Full(Batch),
    /// Every worker has died (engine panic); the pool can never serve
    /// this batch.
    Dead(Batch),
}

struct Shared {
    /// The bounded dispatch queue (see [`super::queue`]) — queue,
    /// per-worker busy slots, drain/retire protocol. Extracted so the
    /// loom models check its orderings in isolation.
    queue: BoundedQueue<Batch>,
    metrics: Arc<Metrics>,
    verify: bool,
    /// Fired after every finished batch — the service's intake loop
    /// turns it into a wake-up message so it never has to poll.
    on_slot_free: Box<dyn Fn() + Send + Sync>,
}

/// A running worker pool. Owned by the service's intake thread;
/// [`Scheduler::shutdown`] drains and joins it.
pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Vec<sync::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("workers", &self.workers.len())
            .finish()
    }
}

impl Scheduler {
    /// Spawn `cfg.workers` workers, each constructing its engine via
    /// `factory` on its own thread. Any construction failure tears the
    /// pool down and is returned synchronously.
    pub fn start(
        cfg: &ServiceConfig,
        factory: Arc<WorkerEngineFactory>,
        metrics: Arc<Metrics>,
        on_slot_free: Box<dyn Fn() + Send + Sync>,
    ) -> Result<Scheduler> {
        let workers = cfg.workers;
        let shared = Arc::new(Shared {
            // Queue bound: 2 batches per worker, the same depth-2
            // stream the single-engine service's channel gave.
            queue: BoundedQueue::new(workers, 2 * workers),
            metrics,
            verify: cfg.verify,
            on_slot_free,
        });

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = shared.clone();
            let factory = factory.clone();
            let cfg = cfg.clone();
            let ready_tx = ready_tx.clone();
            let handle =
                sync::thread::spawn_named(format!("gbs-worker-{w}"), move || {
                    match factory(&cfg, w) {
                        Ok(engine) => {
                            let _ = ready_tx.send(Ok(()));
                            // Release the readiness channel before serving:
                            // if a *sibling* factory panics (drops its
                            // sender without sending), `start` must see the
                            // disconnect rather than block on workers that
                            // are already in their serve loop.
                            drop(ready_tx);
                            worker_loop(w, engine, &shared);
                        }
                        Err(e) => {
                            shared.queue.retire(w);
                            let _ = ready_tx.send(Err(e));
                        }
                    }
                });
            handles.push(handle);
        }
        drop(ready_tx);

        let mut first_err = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err.or_else(|| {
                        Some(Error::Coordinator(
                            "worker thread died during engine construction".into(),
                        ))
                    });
                    break;
                }
            }
        }
        let scheduler = Scheduler {
            shared,
            workers: handles,
        };
        match first_err {
            None => Ok(scheduler),
            Some(e) => {
                // Tear down the workers that did come up.
                scheduler.shutdown();
                Err(e)
            }
        }
    }

    /// Number of workers in the pool.
    pub fn worker_count(&self) -> usize {
        self.shared.queue.consumers()
    }

    /// True when a batch dispatched right now could start immediately:
    /// some worker is neither executing nor already promised a queued
    /// batch. The intake loop uses this to skip the batching window on
    /// an unloaded service.
    pub fn has_spare_capacity(&self) -> bool {
        self.shared.queue.has_spare_capacity()
    }

    /// Dispatch without blocking; hands the batch back when the queue is
    /// at capacity (the caller re-queues it and waits for a slot-free
    /// wake-up) or the pool is dead.
    pub fn try_dispatch(&self, batch: Batch) -> std::result::Result<(), DispatchError> {
        match self.shared.queue.try_push(batch) {
            Ok(depth) => {
                self.record_depth(depth);
                Ok(())
            }
            Err(PushError::Full(batch)) => Err(DispatchError::Full(batch)),
            Err(PushError::Dead(batch)) => Err(DispatchError::Dead(batch)),
        }
    }

    /// Dispatch, waiting for queue capacity (shutdown drain — admitted
    /// work must reach a worker even under a full queue). Hands the
    /// batch back only if every worker has died.
    pub fn dispatch_blocking(&self, batch: Batch) -> std::result::Result<(), Batch> {
        let depth = self.shared.queue.push_blocking(batch)?;
        self.record_depth(depth);
        Ok(())
    }

    fn record_depth(&self, depth: usize) {
        let depth = depth as u64;
        self.shared.metrics.record_max("scheduler_queue_depth_peak", depth);
        self.shared.metrics.incr("scheduler_queue_depth_sum", depth);
        self.shared.metrics.incr("scheduler_queue_depth_samples", 1);
    }

    /// Drain and stop: workers finish every queued batch, then exit;
    /// returns once all worker threads have been joined.
    pub fn shutdown(self) {
        self.shared.queue.drain();
        for handle in self.workers {
            let _ = handle.join();
        }
    }
}

fn worker_loop(worker: usize, mut engine: Box<dyn SortEngine>, shared: &Shared) {
    // Runs on every exit path, *including an engine panic*: clears the
    // worker's in-flight slot, retires it from the live count and wakes
    // anyone waiting, so a dead pool can never strand a dispatcher on
    // the slots condvar. (The panicked batch's response channels drop
    // with the unwound stack — its callers see a disconnect, exactly
    // like the old single-engine-thread service.)
    struct Retire<'a> {
        shared: &'a Shared,
        worker: usize,
    }
    impl Drop for Retire<'_> {
        fn drop(&mut self) {
            self.shared.queue.retire(self.worker);
            (self.shared.on_slot_free)();
        }
    }
    let _retire = Retire { shared, worker };

    // Lifetime coalescing totals at the last poll — deltas flow into
    // the shared metrics after every batch (the engine itself has no
    // metrics handle).
    let mut coalesced_seen = engine.coalesced_totals().unwrap_or_default();
    // Same delta scheme for the adaptive front-end's plan decisions.
    let mut plan_seen = engine.plan_totals().unwrap_or_default();

    loop {
        // `pop` marks this worker's busy slot and wakes a dispatcher
        // blocked on capacity; `None` means drained — exit.
        let Some(batch) = shared.queue.pop(worker) else { return };

        let outcomes = execute_batch(worker, engine.as_mut(), batch, shared);

        if let Some(totals) = engine.coalesced_totals() {
            if totals != coalesced_seen {
                shared.metrics.incr(
                    "coalesced_requests",
                    totals.requests - coalesced_seen.requests,
                );
                shared
                    .metrics
                    .incr("coalesced_groups", totals.groups - coalesced_seen.groups);
                coalesced_seen = totals;
            }
        }

        if let Some(totals) = engine.plan_totals() {
            if totals != plan_seen {
                let m = &shared.metrics;
                m.incr("adaptive_requests", totals.requests - plan_seen.requests);
                m.incr(
                    "adaptive_early_exit_sorted",
                    totals.early_exit_sorted - plan_seen.early_exit_sorted,
                );
                m.incr(
                    "adaptive_early_exit_reverse",
                    totals.early_exit_reverse - plan_seen.early_exit_reverse,
                );
                m.incr(
                    "adaptive_chose_radix",
                    totals.chose_radix - plan_seen.chose_radix,
                );
                m.incr(
                    "adaptive_chose_comparison",
                    totals.chose_comparison - plan_seen.chose_comparison,
                );
                plan_seen = totals;
            }
        }

        shared.queue.finish(worker);
        (shared.on_slot_free)();

        // Deliver only after freeing the slot (see module docs).
        for (respond_to, admitted_at, outcome) in outcomes {
            shared.metrics.observe(
                "request_latency",
                Instant::now().saturating_duration_since(admitted_at),
            );
            let _ = respond_to.send(outcome);
        }
    }
}

type Delivery = (
    mpsc::Sender<Result<SortResponse>>,
    Instant,
    Result<SortResponse>,
);

/// Run one batch on this worker's engine and prepare the responses
/// (identical per-request semantics to the old single-engine loop: jobs
/// fail individually, verify/self-check modes check each output against
/// its own input). Engines sort ascending; the requested direction is
/// applied here, uniformly, before verification.
fn execute_batch(
    worker: usize,
    engine: &mut dyn SortEngine,
    batch: Batch,
    shared: &Shared,
) -> Vec<Delivery> {
    let dispatched = Instant::now();
    let batch_size = batch.len();
    let mut reqs = batch.requests;
    let jobs: Vec<JobData> = reqs
        .iter_mut()
        .map(|r| JobData {
            keys: std::mem::take(&mut r.request.keys),
            payload: r.request.payload.take(),
        })
        .collect();
    // Clone inputs only for requests that will be verified.
    let inputs: Vec<Option<JobData>> = reqs
        .iter()
        .zip(&jobs)
        .map(|(r, job)| (shared.verify || r.request.self_check).then(|| job.clone()))
        .collect();
    let mut results = engine.sort_batch(jobs);
    debug_assert_eq!(results.len(), batch_size, "engine must answer every job");
    for (req, result) in reqs.iter().zip(results.iter_mut()) {
        if req.request.descending {
            if let Ok(job) = result {
                job.reverse();
            }
        }
    }
    let service_ms = dispatched.elapsed().as_secs_f64() * 1e3;
    let metrics = &shared.metrics;
    metrics.observe_ms("engine_batch", service_ms);
    metrics.observe_ms(&format!("worker_{worker}_busy"), service_ms);
    metrics.incr(&format!("worker_{worker}_batches"), 1);

    reqs.into_iter()
        .zip(results)
        .enumerate()
        .map(|(i, (req, result))| {
            let queue_ms = dispatched
                .saturating_duration_since(req.admitted_at)
                .as_secs_f64()
                * 1e3;
            metrics.observe_ms("queue_delay", queue_ms);
            let outcome = result.and_then(|job| {
                if let Some(input) = &inputs[i] {
                    engine::verify_outcome(input, &job, req.request.descending)?;
                }
                metrics.incr("requests_completed", 1);
                metrics.incr("keys_sorted", job.keys.len() as u64);
                // Decision observability is opt-in per request: a tag
                // ending in `#plan` gets the engine's latest
                // [`crate::algos::adaptive::PlanChoice`] summary
                // appended (engines without a front-end echo the tag
                // unchanged, like every other tag).
                let mut tag = req.request.tag;
                if let Some(t) = tag.as_mut() {
                    if t.ends_with("#plan") {
                        if let Some(choice) = engine.last_plan_choice() {
                            t.push(';');
                            t.push_str(&choice.summary());
                        }
                    }
                }
                Ok(SortResponse {
                    id: req.id,
                    keys: job.keys,
                    payload: job.payload,
                    tag,
                    engine: engine.kind(),
                    worker,
                    batch_size,
                    queue_ms,
                    service_ms,
                })
            });
            if outcome.is_err() {
                metrics.incr("requests_failed", 1);
            }
            (req.respond_to, req.admitted_at, outcome)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::request::{PendingRequest, SortRequest};
    use crate::KeyData;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    struct CountingEngine;
    impl SortEngine for CountingEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Native
        }
        fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
            jobs.into_iter()
                .map(|mut j| {
                    if let KeyData::U32(v) = &mut j.keys {
                        v.sort_unstable();
                    }
                    Ok(j)
                })
                .collect()
        }
    }

    fn batch_of(keys: Vec<u32>) -> (Batch, mpsc::Receiver<Result<SortResponse>>) {
        let (tx, rx) = mpsc::channel();
        let n = keys.len();
        let batch = Batch {
            requests: vec![PendingRequest {
                id: 1,
                request: SortRequest::new(keys),
                admitted_at: Instant::now(),
                respond_to: tx,
            }],
            total_keys: n,
        };
        (batch, rx)
    }

    fn test_cfg(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn pool_executes_and_drains() {
        let metrics = Arc::new(Metrics::new());
        let freed = Arc::new(AtomicUsize::new(0));
        let freed_hook = freed.clone();
        let scheduler = Scheduler::start(
            &test_cfg(3),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(move || {
                freed_hook.fetch_add(1, Ordering::SeqCst);
            }),
        )
        .unwrap();
        assert_eq!(scheduler.worker_count(), 3);
        assert!(scheduler.has_spare_capacity());

        let mut rxs = Vec::new();
        for i in 0..10u32 {
            let (batch, rx) = batch_of(vec![3 + i, 1, 2]);
            scheduler.dispatch_blocking(batch).unwrap();
            rxs.push((i, rx));
        }
        scheduler.shutdown();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.keys_u32(), &[1, 2, 3 + i]);
            assert!(out.worker < 3);
            assert_eq!(out.batch_size, 1);
        }
        // 10 batch completions + one retirement notification per worker.
        assert_eq!(freed.load(Ordering::SeqCst), 13);
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["requests_completed"], 10);
        assert!(snap.counters["scheduler_queue_depth_peak"] >= 1);
        assert_eq!(snap.timers["request_latency"].count, 10);
        // Every participating worker recorded busy time.
        let busy: u64 = (0..3)
            .filter_map(|w| snap.timers.get(&format!("worker_{w}_busy")))
            .map(|h| h.count)
            .sum();
        assert_eq!(busy, 10);
    }

    #[test]
    fn plan_totals_flow_to_metrics_and_plan_tags() {
        use crate::algos::adaptive::{Choice, PlanChoice, PlanTotals};
        // An engine with an adaptive front-end: totals grow per job,
        // the last choice is available for tag echoing.
        struct PlannyEngine {
            totals: PlanTotals,
        }
        impl SortEngine for PlannyEngine {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                self.totals.requests += jobs.len() as u64;
                self.totals.chose_radix += jobs.len() as u64;
                jobs.into_iter()
                    .map(|mut j| {
                        if let KeyData::U32(v) = &mut j.keys {
                            v.sort_unstable();
                        }
                        Ok(j)
                    })
                    .collect()
            }
            fn plan_totals(&self) -> Option<PlanTotals> {
                Some(self.totals)
            }
            fn last_plan_choice(&self) -> Option<PlanChoice> {
                (self.totals.requests > 0).then_some(PlanChoice {
                    chosen: Choice::Radix,
                    n: 3,
                    predicted_ms: 0.5,
                    actual_ms: 0.4,
                    planned_passes: 3,
                    duplicate_density: 0.0,
                })
            }
        }
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(PlannyEngine {
                    totals: PlanTotals::default(),
                }) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
        )
        .unwrap();

        let tagged = |tag: &str| {
            let (tx, rx) = mpsc::channel();
            let batch = Batch {
                requests: vec![PendingRequest {
                    id: 1,
                    request: SortRequest::tagged(vec![3u32, 1, 2], tag),
                    admitted_at: Instant::now(),
                    respond_to: tx,
                }],
                total_keys: 3,
            };
            (batch, rx)
        };
        // A `#plan` tag gets the choice summary appended…
        let (batch, rx_plan) = tagged("probe#plan");
        scheduler.dispatch_blocking(batch).unwrap();
        // …any other tag is echoed untouched.
        let (batch, rx_other) = tagged("probe");
        scheduler.dispatch_blocking(batch).unwrap();
        scheduler.shutdown();

        let out = rx_plan.recv().unwrap().unwrap();
        let tag = out.tag.unwrap();
        assert!(
            tag.starts_with("probe#plan;choice=radix;n=3;"),
            "unexpected tag {tag:?}"
        );
        assert_eq!(
            rx_other.recv().unwrap().unwrap().tag.as_deref(),
            Some("probe")
        );
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["adaptive_requests"], 2);
        assert_eq!(snap.counters["adaptive_chose_radix"], 2);
        assert_eq!(snap.counters["adaptive_early_exit_sorted"], 0);
    }

    #[test]
    fn try_dispatch_reports_full() {
        // One worker that blocks forever until drain: capacity 2 fills.
        struct Stuck(Arc<(Mutex<bool>, Condvar)>);
        impl SortEngine for Stuck {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                let (lock, cv) = &*self.0;
                let mut released = lock.lock().unwrap();
                while !*released {
                    released = cv.wait(released).unwrap();
                }
                jobs.into_iter().map(Ok).collect()
            }
        }
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let gate_engine = gate.clone();
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(move |_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(Stuck(gate_engine.clone())) as Box<dyn SortEngine>)
            }),
            metrics,
            Box::new(|| {}),
        )
        .unwrap();

        let mut rxs = Vec::new();
        // First batch starts executing…
        let (first, rx) = batch_of(vec![2, 1]);
        scheduler.try_dispatch(first).unwrap();
        rxs.push(rx);
        while scheduler.shared.queue.active_count() == 0 {
            std::thread::yield_now();
        }
        // …two more fill the bounded queue; the fourth is refused and
        // handed back intact.
        for _ in 0..2 {
            let (batch, rx) = batch_of(vec![2, 1]);
            scheduler.try_dispatch(batch).unwrap();
            rxs.push(rx);
        }
        let (overflow, _overflow_rx) = batch_of(vec![2, 1]);
        match scheduler.try_dispatch(overflow).unwrap_err() {
            DispatchError::Full(batch) => assert_eq!(batch.len(), 1),
            DispatchError::Dead(_) => panic!("pool is alive"),
        }
        assert!(!scheduler.has_spare_capacity());

        // Release the engine; drain completes all accepted batches.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        scheduler.shutdown();
        let done = rxs
            .iter()
            .filter(|rx| matches!(rx.try_recv(), Ok(Ok(_))))
            .count();
        assert_eq!(done, 3);
    }

    #[test]
    fn panicked_workers_retire_and_dispatch_fails_dead() {
        struct PanicEngine;
        impl SortEngine for PanicEngine {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, _jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                panic!("engine crashed");
            }
        }
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(PanicEngine) as Box<dyn SortEngine>)
            }),
            metrics,
            Box::new(|| {}),
        )
        .unwrap();
        let (batch, rx) = batch_of(vec![2, 1]);
        scheduler.try_dispatch(batch).unwrap();
        // The caller sees a disconnect, not a hang.
        assert!(rx.recv().is_err());
        // The response channels drop mid-unwind, before the retire
        // guard runs — wait for the bookkeeping to settle.
        while scheduler.shared.queue.live_consumers() > 0 {
            std::thread::yield_now();
        }
        // The pool is now dead: both dispatch paths hand the batch back
        // instead of stranding it (or the dispatcher).
        let (batch, _rx2) = batch_of(vec![2, 1]);
        let batch = match scheduler.try_dispatch(batch) {
            Err(DispatchError::Dead(b)) => b,
            other => panic!("expected dead pool, got {other:?}"),
        };
        assert!(scheduler.dispatch_blocking(batch).is_err());
        scheduler.shutdown();
    }

    #[test]
    fn construction_failure_is_synchronous_and_joins() {
        let metrics = Arc::new(Metrics::new());
        let err = Scheduler::start(
            &test_cfg(4),
            Arc::new(|_cfg: &ServiceConfig, w: usize| {
                if w == 2 {
                    Err(Error::Coordinator("worker 2 exploded".into()))
                } else {
                    Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
                }
            }),
            metrics,
            Box::new(|| {}),
        )
        .unwrap_err();
        assert!(err.to_string().contains("exploded"), "{err}");
    }
}

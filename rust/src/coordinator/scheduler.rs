//! The multi-worker scheduler: a pool of N engine workers behind one
//! condvar-signalled admission queue.
//!
//! The paper's determinism claim is what makes this safe to build: a
//! batch's outcome depends only on each job's own keys (every engine
//! sorts jobs independently; a sorted key sequence is the unique
//! ordering of its bit-pattern multiset, and key–value jobs sort
//! `Record`s whose tie-breaking index makes the order total), so
//! batches may complete **out of order across workers** while every
//! response stays byte-identical to the single-worker service.
//! Per-request oneshot channels deliver results, so completion order
//! never matters to callers.
//!
//! Design:
//! * one `Mutex<State>` guards the dispatch queue and the per-worker
//!   in-flight table; two condvars signal it (`work`: a batch arrived or
//!   drain started, towards workers; `slots`: a batch finished or left
//!   the queue, towards dispatchers);
//! * each worker owns its engine, built **on the worker thread** by the
//!   factory (PJRT state is not `Send`; a sharded engine leases its own
//!   disjoint device subset);
//! * the queue is bounded at `2 × workers` batches so queue-delay
//!   accounting stays honest (a depth-2 stream per worker, like the
//!   single-engine service's depth-2 channel);
//! * `shutdown` drains: workers finish the queue, then exit; no batch
//!   admitted to the scheduler is ever dropped.
//!
//! After finishing a batch a worker first clears its in-flight slot and
//! *then* delivers the outcomes and fires the `on_slot_free` hook — a
//! caller woken by its response often submits immediately, and must see
//! spare capacity (else it eats a full batching wait).

use super::engine::{self, SortEngine};
use super::queue::{BoundedQueue, PushError};
use super::request::{Batch, JobData, PendingRequest, SortResponse};
use crate::config::ServiceConfig;
use crate::error::{Error, FailureClass, Result};
use crate::metrics::Metrics;
use crate::sim::fault::FaultInjector;
use crate::util::backoff::{self, Backoff};
use crate::util::sync::{self as sync, Arc};
use std::sync::mpsc;
use std::time::Instant;

/// Bounded retry budget for a retryable per-request failure (injected
/// device loss that exhausted failover, contained engine panics, …).
/// Attempt-counted — the backoff between attempts paces the worker but
/// never decides the outcome.
const RETRY_MAX_ATTEMPTS: u32 = 3;

/// Builds one worker's engine, on that worker's thread. Called once per
/// worker with the worker index.
pub type WorkerEngineFactory =
    dyn Fn(&ServiceConfig, usize) -> Result<Box<dyn SortEngine>> + Send + Sync;

/// Why a dispatch did not go through. The batch is handed back intact
/// either way.
#[derive(Debug)]
pub enum DispatchError {
    /// The bounded queue is at capacity — re-dispatch after a slot-free
    /// wake-up.
    Full(Batch),
    /// Every worker has died (engine panic); the pool can never serve
    /// this batch.
    Dead(Batch),
}

struct Shared {
    /// The bounded dispatch queue (see [`super::queue`]) — queue,
    /// per-worker busy slots, drain/retire protocol. Extracted so the
    /// loom models check its orderings in isolation.
    queue: BoundedQueue<Batch>,
    metrics: Arc<Metrics>,
    verify: bool,
    /// Deterministic fault injector resolved from `config.fault_plan`
    /// (`None` in production — every probe is a single `Option` check).
    /// Shared with the worker engines and the net tier so rule counters
    /// span the whole service.
    faults: Option<Arc<FaultInjector>>,
    /// Fired after every finished batch — the service's intake loop
    /// turns it into a wake-up message so it never has to poll.
    on_slot_free: Box<dyn Fn() + Send + Sync>,
}

/// A running worker pool. Owned by the service's intake thread;
/// [`Scheduler::shutdown`] drains and joins it.
pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Vec<sync::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("workers", &self.workers.len())
            .finish()
    }
}

impl Scheduler {
    /// Spawn `cfg.workers` workers, each constructing its engine via
    /// `factory` on its own thread. Any construction failure tears the
    /// pool down and is returned synchronously.
    pub fn start(
        cfg: &ServiceConfig,
        factory: Arc<WorkerEngineFactory>,
        metrics: Arc<Metrics>,
        on_slot_free: Box<dyn Fn() + Send + Sync>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Scheduler> {
        let workers = cfg.workers;
        let shared = Arc::new(Shared {
            // Queue bound: 2 batches per worker, the same depth-2
            // stream the single-engine service's channel gave.
            queue: BoundedQueue::new(workers, 2 * workers),
            metrics,
            verify: cfg.verify,
            faults,
            on_slot_free,
        });

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = shared.clone();
            let factory = factory.clone();
            let cfg = cfg.clone();
            let ready_tx = ready_tx.clone();
            let handle =
                sync::thread::spawn_named(format!("gbs-worker-{w}"), move || {
                    match factory(&cfg, w) {
                        Ok(engine) => {
                            let _ = ready_tx.send(Ok(()));
                            // Release the readiness channel before serving:
                            // if a *sibling* factory panics (drops its
                            // sender without sending), `start` must see the
                            // disconnect rather than block on workers that
                            // are already in their serve loop.
                            drop(ready_tx);
                            worker_loop(w, engine, &shared);
                        }
                        Err(e) => {
                            shared.queue.retire(w);
                            let _ = ready_tx.send(Err(e));
                        }
                    }
                });
            handles.push(handle);
        }
        drop(ready_tx);

        let mut first_err = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err.or_else(|| {
                        Some(Error::Coordinator(
                            "worker thread died during engine construction".into(),
                        ))
                    });
                    break;
                }
            }
        }
        let scheduler = Scheduler {
            shared,
            workers: handles,
        };
        match first_err {
            None => Ok(scheduler),
            Some(e) => {
                // Tear down the workers that did come up.
                scheduler.shutdown();
                Err(e)
            }
        }
    }

    /// Number of workers in the pool.
    pub fn worker_count(&self) -> usize {
        self.shared.queue.consumers()
    }

    /// True when a batch dispatched right now could start immediately:
    /// some worker is neither executing nor already promised a queued
    /// batch. The intake loop uses this to skip the batching window on
    /// an unloaded service.
    pub fn has_spare_capacity(&self) -> bool {
        self.shared.queue.has_spare_capacity()
    }

    /// Dispatch without blocking; hands the batch back when the queue is
    /// at capacity (the caller re-queues it and waits for a slot-free
    /// wake-up) or the pool is dead.
    pub fn try_dispatch(&self, batch: Batch) -> std::result::Result<(), DispatchError> {
        match self.shared.queue.try_push(batch) {
            Ok(depth) => {
                self.record_depth(depth);
                Ok(())
            }
            Err(PushError::Full(batch)) => Err(DispatchError::Full(batch)),
            Err(PushError::Dead(batch)) => Err(DispatchError::Dead(batch)),
        }
    }

    /// Dispatch, waiting for queue capacity (shutdown drain — admitted
    /// work must reach a worker even under a full queue). Hands the
    /// batch back only if every worker has died.
    pub fn dispatch_blocking(&self, batch: Batch) -> std::result::Result<(), Batch> {
        let depth = self.shared.queue.push_blocking(batch)?;
        self.record_depth(depth);
        Ok(())
    }

    fn record_depth(&self, depth: usize) {
        let depth = depth as u64;
        self.shared.metrics.record_max("scheduler_queue_depth_peak", depth);
        self.shared.metrics.incr("scheduler_queue_depth_sum", depth);
        self.shared.metrics.incr("scheduler_queue_depth_samples", 1);
    }

    /// Drain and stop: workers finish every queued batch, then exit;
    /// returns once all worker threads have been joined.
    pub fn shutdown(self) {
        self.shared.queue.drain();
        for handle in self.workers {
            let _ = handle.join();
        }
    }
}

fn worker_loop(worker: usize, mut engine: Box<dyn SortEngine>, shared: &Shared) {
    // Runs on every exit path, *including an engine panic*: clears the
    // worker's in-flight slot, retires it from the live count and wakes
    // anyone waiting, so a dead pool can never strand a dispatcher on
    // the slots condvar. (The panicked batch's response channels drop
    // with the unwound stack — its callers see a disconnect, exactly
    // like the old single-engine-thread service.)
    struct Retire<'a> {
        shared: &'a Shared,
        worker: usize,
    }
    impl Drop for Retire<'_> {
        fn drop(&mut self) {
            self.shared.queue.retire(self.worker);
            (self.shared.on_slot_free)();
        }
    }
    let _retire = Retire { shared, worker };

    // Lifetime coalescing totals at the last poll — deltas flow into
    // the shared metrics after every batch (the engine itself has no
    // metrics handle).
    let mut coalesced_seen = engine.coalesced_totals().unwrap_or_default();
    // Same delta scheme for the adaptive front-end's plan decisions.
    let mut plan_seen = engine.plan_totals().unwrap_or_default();
    // …and for the engine's fault-recovery totals.
    let mut fault_seen = engine.fault_totals().unwrap_or_default();

    loop {
        // `pop` marks this worker's busy slot and wakes a dispatcher
        // blocked on capacity; `None` means drained — exit.
        let Some(batch) = shared.queue.pop(worker) else { return };

        // An armed slow-device rule paces this worker before the batch
        // runs (a stall, never a failure).
        engine::pace_for_injected_slowdown(shared.faults.as_deref(), worker);

        let outcomes = execute_batch(worker, engine.as_mut(), batch, shared);

        if let Some(totals) = engine.coalesced_totals() {
            if totals != coalesced_seen {
                shared.metrics.incr(
                    "coalesced_requests",
                    totals.requests - coalesced_seen.requests,
                );
                shared
                    .metrics
                    .incr("coalesced_groups", totals.groups - coalesced_seen.groups);
                coalesced_seen = totals;
            }
        }

        if let Some(totals) = engine.plan_totals() {
            if totals != plan_seen {
                let m = &shared.metrics;
                m.incr("adaptive_requests", totals.requests - plan_seen.requests);
                m.incr(
                    "adaptive_early_exit_sorted",
                    totals.early_exit_sorted - plan_seen.early_exit_sorted,
                );
                m.incr(
                    "adaptive_early_exit_reverse",
                    totals.early_exit_reverse - plan_seen.early_exit_reverse,
                );
                m.incr(
                    "adaptive_chose_radix",
                    totals.chose_radix - plan_seen.chose_radix,
                );
                m.incr(
                    "adaptive_chose_comparison",
                    totals.chose_comparison - plan_seen.chose_comparison,
                );
                plan_seen = totals;
            }
        }

        if let Some(totals) = engine.fault_totals() {
            if totals != fault_seen {
                shared.metrics.incr(
                    "failover_events",
                    totals.failovers - fault_seen.failovers,
                );
                shared
                    .metrics
                    .record_max("failover_devices_lost", totals.devices_lost);
                fault_seen = totals;
            }
        }

        // The injector's own per-point counters are lifetime totals
        // shared across workers — export as a max, not a delta.
        if let Some(inj) = shared.faults.as_deref() {
            for (point, n) in inj.injected() {
                shared.metrics.record_max(&format!("fault_injected_{point}"), n);
            }
        }

        shared.queue.finish(worker);
        (shared.on_slot_free)();

        // Deliver only after freeing the slot (see module docs).
        for (respond_to, admitted_at, outcome) in outcomes {
            shared.metrics.observe(
                "request_latency",
                Instant::now().saturating_duration_since(admitted_at),
            );
            let _ = respond_to.send(outcome);
        }
    }
}

type Delivery = (
    mpsc::Sender<Result<SortResponse>>,
    Instant,
    Result<SortResponse>,
);

/// Deadline check at a dispatch/retry boundary: `Some(Timeout)` when
/// the request's budget (measured from admission) has passed. Batches
/// already executing always run to completion — this is only consulted
/// between engine dispatches.
fn past_deadline(req: &PendingRequest) -> Option<Error> {
    let ms = req.request.deadline_ms?;
    let waited = Instant::now().saturating_duration_since(req.admitted_at);
    (waited.as_millis() as u64 > ms).then(|| {
        Error::Timeout(format!(
            "request {} exceeded its {ms} ms deadline after {} ms",
            req.id,
            waited.as_millis()
        ))
    })
}

/// One panic-contained engine dispatch. An injected `worker_panic`
/// fires *inside* the contained scope, so fault plans exercise the real
/// recovery path. Returns the panic message on unwind; the engine
/// object itself stays usable (every engine resets per-job device
/// state, and the facade's poison policy keeps shared structures sane).
fn run_engine(
    worker: usize,
    engine: &mut dyn SortEngine,
    jobs: Vec<JobData>,
    faults: Option<&FaultInjector>,
) -> std::result::Result<Vec<Result<JobData>>, String> {
    let n = jobs.len();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(inj) = faults {
            if inj.worker_panic(worker) {
                panic!("injected worker panic (fault plan)");
            }
        }
        engine.sort_batch(jobs)
    }));
    match caught {
        Ok(results) => {
            debug_assert_eq!(results.len(), n, "engine must answer every job");
            Ok(results)
        }
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(format!("worker {worker} engine panicked: {msg}"))
        }
    }
}

/// Run one batch on this worker's engine and prepare the responses
/// (identical per-request semantics to the old single-engine loop: jobs
/// fail individually, verify/self-check modes check each output against
/// its own input). Engines sort ascending; the requested direction is
/// applied here, uniformly, before verification.
///
/// Resilience layers, in order:
/// 1. requests past their deadline fail typed before any engine work;
/// 2. an engine panic (real or injected) is contained to this batch —
///    the worker survives;
/// 3. retryable failures are re-dispatched *individually* with bounded,
///    attempt-counted backoff, which both recovers transient faults and
///    isolates a poisoned job from its batch-mates.
fn execute_batch(
    worker: usize,
    engine: &mut dyn SortEngine,
    batch: Batch,
    shared: &Shared,
) -> Vec<Delivery> {
    let dispatched = Instant::now();
    let batch_size = batch.len();
    let mut reqs = batch.requests;
    let faults = shared.faults.as_deref();

    // Layer 1: deadline check at the dispatch boundary. An expired
    // request's slot becomes an empty job (keeps indices aligned, costs
    // the engine nothing) and its result is forced to Timeout below.
    let timed_out: Vec<Option<Error>> = reqs.iter().map(past_deadline).collect();

    let jobs: Vec<JobData> = reqs
        .iter_mut()
        .zip(&timed_out)
        .map(|(r, expired)| {
            if expired.is_some() {
                JobData::default()
            } else {
                JobData {
                    keys: std::mem::take(&mut r.request.keys),
                    payload: r.request.payload.take(),
                }
            }
        })
        .collect();
    // Clone inputs for requests that will be verified — and for
    // everyone when a fault plan is armed: retry needs the original
    // bytes back after a failed dispatch, and chaos runs want every
    // recovered response verified against its input.
    let inputs: Vec<Option<JobData>> = reqs
        .iter()
        .zip(&jobs)
        .map(|(r, job)| {
            (shared.verify || r.request.self_check || faults.is_some()).then(|| job.clone())
        })
        .collect();

    // Layer 2: panic-contained dispatch of the whole batch.
    let mut results: Vec<Result<JobData>> = match run_engine(worker, engine, jobs, faults) {
        Ok(results) => results,
        Err(msg) => {
            shared.metrics.incr("fault_worker_panics_contained", 1);
            (0..batch_size)
                .map(|_| Err(Error::Internal(msg.clone())))
                .collect()
        }
    };

    for (result, expired) in results.iter_mut().zip(timed_out) {
        if let Some(e) = expired {
            shared.metrics.incr("requests_timed_out", 1);
            *result = Err(e);
        }
    }

    // Layer 3: bounded per-request retry of retryable failures with a
    // captured input. Deadlines are re-checked at every boundary.
    for i in 0..batch_size {
        let retryable =
            matches!(&results[i], Err(e) if e.failure_class() == FailureClass::Retryable);
        if !retryable {
            continue;
        }
        let Some(input) = &inputs[i] else { continue };
        let mut attempt: u32 = 0;
        loop {
            if let Some(e) = past_deadline(&reqs[i]) {
                shared.metrics.incr("requests_timed_out", 1);
                results[i] = Err(e);
                break;
            }
            if attempt >= RETRY_MAX_ATTEMPTS {
                shared.metrics.incr("retry_exhausted", 1);
                break;
            }
            backoff::sleep_backoff(&Backoff::SCHEDULER, attempt);
            attempt += 1;
            shared.metrics.incr("retry_attempts", 1);
            match run_engine(worker, engine, vec![input.clone()], faults) {
                Ok(mut one) => {
                    let outcome = match one.pop() {
                        Some(r) => r,
                        None => Err(Error::Internal(
                            "engine answered nothing for a retried job".into(),
                        )),
                    };
                    let recovered = outcome.is_ok();
                    let again = matches!(
                        &outcome,
                        Err(e) if e.failure_class() == FailureClass::Retryable
                    );
                    results[i] = outcome;
                    if recovered {
                        shared.metrics.incr("retry_recovered", 1);
                        break;
                    }
                    if !again {
                        break;
                    }
                }
                Err(msg) => {
                    // Panicked again, alone: contained, still retryable
                    // (bounded by the attempt budget above).
                    shared.metrics.incr("fault_worker_panics_contained", 1);
                    results[i] = Err(Error::Internal(msg));
                }
            }
        }
    }

    debug_assert_eq!(results.len(), batch_size, "engine must answer every job");
    for (req, result) in reqs.iter().zip(results.iter_mut()) {
        if req.request.descending {
            if let Ok(job) = result {
                job.reverse();
            }
        }
    }
    let service_ms = dispatched.elapsed().as_secs_f64() * 1e3;
    let metrics = &shared.metrics;
    metrics.observe_ms("engine_batch", service_ms);
    metrics.observe_ms(&format!("worker_{worker}_busy"), service_ms);
    metrics.incr(&format!("worker_{worker}_batches"), 1);

    reqs.into_iter()
        .zip(results)
        .enumerate()
        .map(|(i, (req, result))| {
            let queue_ms = dispatched
                .saturating_duration_since(req.admitted_at)
                .as_secs_f64()
                * 1e3;
            metrics.observe_ms("queue_delay", queue_ms);
            let outcome = result.and_then(|job| {
                if let Some(input) = &inputs[i] {
                    engine::verify_outcome(input, &job, req.request.descending)?;
                }
                metrics.incr("requests_completed", 1);
                metrics.incr("keys_sorted", job.keys.len() as u64);
                // Decision observability is opt-in per request: a tag
                // ending in `#plan` gets the engine's latest
                // [`crate::algos::adaptive::PlanChoice`] summary
                // appended (engines without a front-end echo the tag
                // unchanged, like every other tag).
                let mut tag = req.request.tag;
                if let Some(t) = tag.as_mut() {
                    if t.ends_with("#plan") {
                        if let Some(choice) = engine.last_plan_choice() {
                            t.push(';');
                            t.push_str(&choice.summary());
                        }
                    }
                }
                Ok(SortResponse {
                    id: req.id,
                    keys: job.keys,
                    payload: job.payload,
                    tag,
                    engine: engine.kind(),
                    worker,
                    batch_size,
                    queue_ms,
                    service_ms,
                })
            });
            if outcome.is_err() {
                metrics.incr("requests_failed", 1);
            }
            (req.respond_to, req.admitted_at, outcome)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::request::{PendingRequest, SortRequest};
    use crate::KeyData;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    struct CountingEngine;
    impl SortEngine for CountingEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Native
        }
        fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
            jobs.into_iter()
                .map(|mut j| {
                    if let KeyData::U32(v) = &mut j.keys {
                        v.sort_unstable();
                    }
                    Ok(j)
                })
                .collect()
        }
    }

    fn batch_of(keys: Vec<u32>) -> (Batch, mpsc::Receiver<Result<SortResponse>>) {
        let (tx, rx) = mpsc::channel();
        let n = keys.len();
        let batch = Batch {
            requests: vec![PendingRequest {
                id: 1,
                request: SortRequest::new(keys),
                admitted_at: Instant::now(),
                respond_to: tx,
            }],
            total_keys: n,
        };
        (batch, rx)
    }

    fn test_cfg(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn pool_executes_and_drains() {
        let metrics = Arc::new(Metrics::new());
        let freed = Arc::new(AtomicUsize::new(0));
        let freed_hook = freed.clone();
        let scheduler = Scheduler::start(
            &test_cfg(3),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(move || {
                freed_hook.fetch_add(1, Ordering::SeqCst);
            }),
            None,
        )
        .unwrap();
        assert_eq!(scheduler.worker_count(), 3);
        assert!(scheduler.has_spare_capacity());

        let mut rxs = Vec::new();
        for i in 0..10u32 {
            let (batch, rx) = batch_of(vec![3 + i, 1, 2]);
            scheduler.dispatch_blocking(batch).unwrap();
            rxs.push((i, rx));
        }
        scheduler.shutdown();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.keys_u32(), &[1, 2, 3 + i]);
            assert!(out.worker < 3);
            assert_eq!(out.batch_size, 1);
        }
        // 10 batch completions + one retirement notification per worker.
        assert_eq!(freed.load(Ordering::SeqCst), 13);
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["requests_completed"], 10);
        assert!(snap.counters["scheduler_queue_depth_peak"] >= 1);
        assert_eq!(snap.timers["request_latency"].count, 10);
        // Every participating worker recorded busy time.
        let busy: u64 = (0..3)
            .filter_map(|w| snap.timers.get(&format!("worker_{w}_busy")))
            .map(|h| h.count)
            .sum();
        assert_eq!(busy, 10);
    }

    #[test]
    fn plan_totals_flow_to_metrics_and_plan_tags() {
        use crate::algos::adaptive::{Choice, PlanChoice, PlanTotals};
        // An engine with an adaptive front-end: totals grow per job,
        // the last choice is available for tag echoing.
        struct PlannyEngine {
            totals: PlanTotals,
        }
        impl SortEngine for PlannyEngine {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                self.totals.requests += jobs.len() as u64;
                self.totals.chose_radix += jobs.len() as u64;
                jobs.into_iter()
                    .map(|mut j| {
                        if let KeyData::U32(v) = &mut j.keys {
                            v.sort_unstable();
                        }
                        Ok(j)
                    })
                    .collect()
            }
            fn plan_totals(&self) -> Option<PlanTotals> {
                Some(self.totals)
            }
            fn last_plan_choice(&self) -> Option<PlanChoice> {
                (self.totals.requests > 0).then_some(PlanChoice {
                    chosen: Choice::Radix,
                    n: 3,
                    predicted_ms: 0.5,
                    actual_ms: 0.4,
                    planned_passes: 3,
                    duplicate_density: 0.0,
                })
            }
        }
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(PlannyEngine {
                    totals: PlanTotals::default(),
                }) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
            None,
        )
        .unwrap();

        let tagged = |tag: &str| {
            let (tx, rx) = mpsc::channel();
            let batch = Batch {
                requests: vec![PendingRequest {
                    id: 1,
                    request: SortRequest::tagged(vec![3u32, 1, 2], tag),
                    admitted_at: Instant::now(),
                    respond_to: tx,
                }],
                total_keys: 3,
            };
            (batch, rx)
        };
        // A `#plan` tag gets the choice summary appended…
        let (batch, rx_plan) = tagged("probe#plan");
        scheduler.dispatch_blocking(batch).unwrap();
        // …any other tag is echoed untouched.
        let (batch, rx_other) = tagged("probe");
        scheduler.dispatch_blocking(batch).unwrap();
        scheduler.shutdown();

        let out = rx_plan.recv().unwrap().unwrap();
        let tag = out.tag.unwrap();
        assert!(
            tag.starts_with("probe#plan;choice=radix;n=3;"),
            "unexpected tag {tag:?}"
        );
        assert_eq!(
            rx_other.recv().unwrap().unwrap().tag.as_deref(),
            Some("probe")
        );
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["adaptive_requests"], 2);
        assert_eq!(snap.counters["adaptive_chose_radix"], 2);
        assert_eq!(snap.counters["adaptive_early_exit_sorted"], 0);
    }

    #[test]
    fn try_dispatch_reports_full() {
        // One worker that blocks forever until drain: capacity 2 fills.
        struct Stuck(Arc<(Mutex<bool>, Condvar)>);
        impl SortEngine for Stuck {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                let (lock, cv) = &*self.0;
                let mut released = lock.lock().unwrap();
                while !*released {
                    released = cv.wait(released).unwrap();
                }
                jobs.into_iter().map(Ok).collect()
            }
        }
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let gate_engine = gate.clone();
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(move |_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(Stuck(gate_engine.clone())) as Box<dyn SortEngine>)
            }),
            metrics,
            Box::new(|| {}),
            None,
        )
        .unwrap();

        let mut rxs = Vec::new();
        // First batch starts executing…
        let (first, rx) = batch_of(vec![2, 1]);
        scheduler.try_dispatch(first).unwrap();
        rxs.push(rx);
        while scheduler.shared.queue.active_count() == 0 {
            std::thread::yield_now();
        }
        // …two more fill the bounded queue; the fourth is refused and
        // handed back intact.
        for _ in 0..2 {
            let (batch, rx) = batch_of(vec![2, 1]);
            scheduler.try_dispatch(batch).unwrap();
            rxs.push(rx);
        }
        let (overflow, _overflow_rx) = batch_of(vec![2, 1]);
        match scheduler.try_dispatch(overflow).unwrap_err() {
            DispatchError::Full(batch) => assert_eq!(batch.len(), 1),
            DispatchError::Dead(_) => panic!("pool is alive"),
        }
        assert!(!scheduler.has_spare_capacity());

        // Release the engine; drain completes all accepted batches.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        scheduler.shutdown();
        let done = rxs
            .iter()
            .filter(|rx| matches!(rx.try_recv(), Ok(Ok(_))))
            .count();
        assert_eq!(done, 3);
    }

    #[test]
    fn engine_panics_are_contained_and_the_worker_survives() {
        // An engine that always panics: every request fails with a
        // typed Internal error (never a hang, never a dropped channel)
        // and the worker keeps serving — the pool never goes dead.
        struct PanicEngine;
        impl SortEngine for PanicEngine {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, _jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                panic!("engine crashed");
            }
        }
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(PanicEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
            None,
        )
        .unwrap();
        let (batch, rx) = batch_of(vec![2, 1]);
        scheduler.try_dispatch(batch).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, Error::Internal(_)), "{err}");
        assert_eq!(err.failure_class(), FailureClass::Retryable);
        // The worker survived the panic and still serves (and fails)
        // follow-up batches — no dead pool, no stranded dispatcher.
        let (batch, rx2) = batch_of(vec![4, 3]);
        scheduler.dispatch_blocking(batch).unwrap();
        assert!(rx2.recv().unwrap().is_err());
        assert!(scheduler.has_spare_capacity());
        scheduler.shutdown();
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["fault_worker_panics_contained"], 2);
        assert_eq!(snap.counters["requests_failed"], 2);
    }

    #[test]
    fn engine_panic_is_isolated_per_request_when_inputs_are_captured() {
        // A poisoned job (key 666) panics the engine; with verify on
        // (inputs captured) the retry pass re-dispatches each job alone,
        // so the batch-mate recovers and only the poisoned request
        // fails — with a typed error, after a bounded retry budget.
        struct PoisonEngine;
        impl SortEngine for PoisonEngine {
            fn kind(&self) -> EngineKind {
                EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
                jobs.into_iter()
                    .map(|mut j| {
                        if let KeyData::U32(v) = &mut j.keys {
                            if v.contains(&666) {
                                panic!("poisoned job");
                            }
                            v.sort_unstable();
                        }
                        Ok(j)
                    })
                    .collect()
            }
        }
        let metrics = Arc::new(Metrics::new());
        let cfg = ServiceConfig {
            workers: 1,
            verify: true,
            ..Default::default()
        };
        let scheduler = Scheduler::start(
            &cfg,
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(PoisonEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
            None,
        )
        .unwrap();
        let (tx1, rx1) = mpsc::channel();
        let (tx2, rx2) = mpsc::channel();
        let batch = Batch {
            requests: vec![
                PendingRequest {
                    id: 1,
                    request: SortRequest::new(vec![666u32, 3, 1]),
                    admitted_at: Instant::now(),
                    respond_to: tx1,
                },
                PendingRequest {
                    id: 2,
                    request: SortRequest::new(vec![9u32, 8, 7]),
                    admitted_at: Instant::now(),
                    respond_to: tx2,
                },
            ],
            total_keys: 6,
        };
        scheduler.dispatch_blocking(batch).unwrap();
        let err = rx1.recv().unwrap().unwrap_err();
        assert!(matches!(err, Error::Internal(_)), "{err}");
        assert_eq!(rx2.recv().unwrap().unwrap().keys_u32(), &[7, 8, 9]);
        // The worker survived the poisoned job.
        let (batch, rx3) = batch_of(vec![2, 1]);
        scheduler.dispatch_blocking(batch).unwrap();
        assert_eq!(rx3.recv().unwrap().unwrap().keys_u32(), &[1, 2]);
        scheduler.shutdown();
        let snap = metrics.snapshot();
        // Whole batch + 3 solo retries of the poisoned job panicked.
        assert_eq!(snap.counters["fault_worker_panics_contained"], 4);
        assert_eq!(snap.counters["retry_exhausted"], 1);
        assert_eq!(snap.counters["retry_recovered"], 1);
        assert_eq!(snap.counters["requests_failed"], 1);
    }

    #[test]
    fn injected_worker_panic_recovers_by_retry() {
        use crate::sim::FaultPlan;
        let plan = FaultPlan::parse(
            r#"{"version":1,"seed":1,"rules":[{"point":"worker_panic","count":1}]}"#,
        )
        .unwrap();
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
            Some(plan.injector()),
        )
        .unwrap();
        let (batch, rx) = batch_of(vec![5, 3, 4]);
        scheduler.dispatch_blocking(batch).unwrap();
        // The injected panic hits the first dispatch; the bounded retry
        // recovers the request byte-identically.
        assert_eq!(rx.recv().unwrap().unwrap().keys_u32(), &[3, 4, 5]);
        scheduler.shutdown();
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["fault_worker_panics_contained"], 1);
        assert_eq!(snap.counters["retry_recovered"], 1);
        assert_eq!(snap.counters["retry_attempts"], 1);
        assert_eq!(snap.counters["fault_injected_worker_panic"], 1);
    }

    #[test]
    fn expired_deadlines_fail_typed_without_engine_work() {
        use std::time::Duration;
        let metrics = Arc::new(Metrics::new());
        let scheduler = Scheduler::start(
            &test_cfg(1),
            Arc::new(|_cfg: &ServiceConfig, _w: usize| {
                Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
            }),
            metrics.clone(),
            Box::new(|| {}),
            None,
        )
        .unwrap();
        // Admitted 50 ms ago with a 1 ms budget: expired before
        // dispatch, fails typed.
        let (tx, rx) = mpsc::channel();
        let expired_admission = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .unwrap();
        let batch = Batch {
            requests: vec![PendingRequest {
                id: 7,
                request: SortRequest::builder(vec![3u32, 1, 2])
                    .deadline_ms(1)
                    .build()
                    .unwrap(),
                admitted_at: expired_admission,
                respond_to: tx,
            }],
            total_keys: 3,
        };
        scheduler.dispatch_blocking(batch).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert_eq!(err.failure_class(), FailureClass::Fatal);
        // A generous deadline sails through untouched.
        let (tx, rx) = mpsc::channel();
        let batch = Batch {
            requests: vec![PendingRequest {
                id: 8,
                request: SortRequest::builder(vec![3u32, 1, 2])
                    .deadline_ms(60_000)
                    .build()
                    .unwrap(),
                admitted_at: Instant::now(),
                respond_to: tx,
            }],
            total_keys: 3,
        };
        scheduler.dispatch_blocking(batch).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().keys_u32(), &[1, 2, 3]);
        scheduler.shutdown();
        let snap = metrics.snapshot();
        assert_eq!(snap.counters["requests_timed_out"], 1);
        assert_eq!(snap.counters["requests_failed"], 1);
        assert_eq!(snap.counters["requests_completed"], 1);
    }

    #[test]
    fn construction_failure_is_synchronous_and_joins() {
        let metrics = Arc::new(Metrics::new());
        let err = Scheduler::start(
            &test_cfg(4),
            Arc::new(|_cfg: &ServiceConfig, w: usize| {
                if w == 2 {
                    Err(Error::Coordinator("worker 2 exploded".into()))
                } else {
                    Ok(Box::new(CountingEngine) as Box<dyn SortEngine>)
                }
            }),
            metrics,
            Box::new(|| {}),
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("exploded"), "{err}");
    }
}

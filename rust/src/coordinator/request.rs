//! Request/response types of the sort service — the typed job API.
//!
//! A client submits a [`SortRequest`]: a [`KeyData`] vector of any
//! supported [`crate::KeyType`], an optional `u64` payload (key–value
//! sorting — `payload[i]` belongs to `keys[i]` and again after the
//! sort), a sort direction, and an optional per-request self-check.
//! The service answers with a [`SortResponse`] carrying the sorted
//! keys, the permuted payload and the usual service metadata.
//!
//! The classic API (`Vec<u32>` keys in, ascending, no payload) is the
//! `SortRequest::new(vec)` special case and returns byte-identical
//! results to the pre-typed service. `SortJob`/`SortOutcome` remain as
//! aliases for that migration path.

use crate::config::EngineKind;
use crate::error::Result;
use crate::KeyData;
use std::time::Instant;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// A sort job as submitted by a client.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SortRequest {
    /// The keys to sort (any supported key type).
    pub keys: KeyData,
    /// Optional per-key payload values; `payload[i]` belongs to
    /// `keys[i]` on submission and on return. Ascending key–value sorts
    /// are stable (ties keep submission order); a descending response
    /// is the exact reverse of the ascending one, so equal keys come
    /// back in *reverse* submission order. Both are byte-deterministic.
    pub payload: Option<Vec<u64>>,
    /// Sort direction (`false` = ascending, the default).
    pub descending: bool,
    /// Verify this response is a sorted permutation of this request
    /// (with payload pairing) even when the service-wide `verify`
    /// config is off.
    pub self_check: bool,
    /// Optional client-side tag echoed back in the response (workload
    /// name, tenant, …).
    pub tag: Option<String>,
    /// Optional per-request deadline, in milliseconds measured from
    /// admission. A request still waiting or retrying when its deadline
    /// passes fails with a typed [`crate::Error::Timeout`] instead of
    /// occupying the queue forever. `None` (the default) never times
    /// out. The deadline is checked at dispatch and retry boundaries —
    /// a batch already executing runs to completion.
    pub deadline_ms: Option<u64>,
}

/// Legacy name of [`SortRequest`] (pre-typed API).
pub type SortJob = SortRequest;

impl SortRequest {
    /// An ascending, key-only, untagged request — the classic path.
    pub fn new(keys: impl Into<KeyData>) -> Self {
        SortRequest {
            keys: keys.into(),
            ..Default::default()
        }
    }

    /// A tagged key-only request.
    pub fn tagged(keys: impl Into<KeyData>, tag: impl Into<String>) -> Self {
        SortRequest {
            keys: keys.into(),
            tag: Some(tag.into()),
            ..Default::default()
        }
    }

    /// Start building a request with payload/direction/self-check
    /// options.
    pub fn builder(keys: impl Into<KeyData>) -> SortRequestBuilder {
        SortRequestBuilder {
            req: SortRequest::new(keys),
        }
    }

    /// Key count.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the request carries no keys.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Structural validation: the payload (when present) must pair
    /// one-to-one with the keys and fit the record index space
    /// (the shared [`crate::key::validate_key_value`] rule).
    pub fn validate(&self) -> Result<()> {
        if let Some(p) = &self.payload {
            crate::key::validate_key_value(self.keys.len(), p.len())?;
        }
        Ok(())
    }
}

/// Builder for [`SortRequest`] — the typed request surface
/// (`payload`, `descending`, `self_check`, `tag`).
#[derive(Debug, Clone)]
pub struct SortRequestBuilder {
    req: SortRequest,
}

impl SortRequestBuilder {
    /// Attach a per-key payload (`payload[i]` belongs to `keys[i]`).
    pub fn payload(mut self, payload: Vec<u64>) -> Self {
        self.req.payload = Some(payload);
        self
    }

    /// Sort descending instead of ascending.
    pub fn descending(mut self, yes: bool) -> Self {
        self.req.descending = yes;
        self
    }

    /// Force per-request verification of the response.
    pub fn self_check(mut self, yes: bool) -> Self {
        self.req.self_check = yes;
        self
    }

    /// Echo `tag` back in the response.
    pub fn tag(mut self, tag: impl Into<String>) -> Self {
        self.req.tag = Some(tag.into());
        self
    }

    /// Fail the request with [`crate::Error::Timeout`] if it is still
    /// waiting (or retrying) `ms` milliseconds after admission.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.req.deadline_ms = Some(ms);
        self
    }

    /// Validate and finish.
    pub fn build(self) -> Result<SortRequest> {
        self.req.validate()?;
        Ok(self.req)
    }
}

/// The engine-facing slice of one request: keys plus optional payload.
/// Engines sort **ascending by key bits** and keep `payload[i]` married
/// to `keys[i]`; direction is applied by the scheduler after the engine
/// returns (a reversal, identical for every engine).
#[derive(Debug, Clone, Default)]
pub struct JobData {
    /// The keys to sort.
    pub keys: KeyData,
    /// Optional payload, permuted with the keys.
    pub payload: Option<Vec<u64>>,
}

impl JobData {
    /// A key-only job.
    pub fn new(keys: impl Into<KeyData>) -> Self {
        JobData {
            keys: keys.into(),
            payload: None,
        }
    }

    /// Key count.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the job carries no keys.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Reverse keys (and payload) in place — ascending ↔ descending.
    pub fn reverse(&mut self) {
        self.keys.reverse();
        if let Some(p) = &mut self.payload {
            p.reverse();
        }
    }
}

/// A completed sort.
#[derive(Debug, Clone, PartialEq)]
pub struct SortResponse {
    /// Request id assigned by the service.
    pub id: RequestId,
    /// The sorted keys (same [`crate::KeyType`] as the request).
    pub keys: KeyData,
    /// The payload, permuted with the keys (present iff submitted).
    pub payload: Option<Vec<u64>>,
    /// Echoed request tag.
    pub tag: Option<String>,
    /// Which engine served it.
    pub engine: EngineKind,
    /// Index of the scheduler worker that executed the batch (0 for
    /// zero-key jobs, which never reach a worker).
    pub worker: usize,
    /// Requests that shared the engine dispatch with this one.
    pub batch_size: usize,
    /// Time spent queued before dispatch (ms).
    pub queue_ms: f64,
    /// Engine execution time for the whole batch (ms).
    pub service_ms: f64,
}

/// Legacy name of [`SortResponse`] (pre-typed API).
pub type SortOutcome = SortResponse;

impl SortResponse {
    /// The sorted keys as the classic `u32` vector. Panics for other
    /// key types — a convenience for the u32 tests/benches migration.
    pub fn keys_u32(&self) -> &[u32] {
        self.keys.as_u32().expect("response does not hold u32 keys")
    }
}

/// Internal: a job admitted to the queue, waiting for batch assembly.
#[derive(Debug)]
pub struct PendingRequest {
    /// Assigned id.
    pub id: RequestId,
    /// The request.
    pub request: SortRequest,
    /// Admission timestamp (queue-delay accounting).
    pub admitted_at: Instant,
    /// Completion channel back to the caller (a one-shot: the service
    /// sends exactly one outcome).
    pub respond_to: std::sync::mpsc::Sender<crate::error::Result<SortResponse>>,
}

impl PendingRequest {
    /// Key count of the request.
    pub fn len(&self) -> usize {
        self.request.len()
    }

    /// True when the request carries no keys.
    pub fn is_empty(&self) -> bool {
        self.request.is_empty()
    }
}

/// A group of requests dispatched to the engine together.
#[derive(Debug)]
pub struct Batch {
    /// The member requests, in admission order.
    pub requests: Vec<PendingRequest>,
    /// Σ key counts.
    pub total_keys: usize,
}

impl Batch {
    /// Number of member requests.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch holds no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::KeyType;

    #[test]
    fn request_constructors() {
        let j = SortRequest::new(vec![3u32, 1, 2]);
        assert!(j.tag.is_none());
        assert!(!j.descending && !j.self_check && j.payload.is_none());
        assert_eq!(j.keys.key_type(), KeyType::U32);
        let t = SortRequest::tagged(vec![1u32], "bench");
        assert_eq!(t.tag.as_deref(), Some("bench"));
        // Typed constructors infer the key type from the vector.
        assert_eq!(SortRequest::new(vec![1u64]).keys.key_type(), KeyType::U64);
        assert_eq!(SortRequest::new(vec![-1i64]).keys.key_type(), KeyType::I64);
        assert_eq!(
            SortRequest::new(vec![0.5f32]).keys.key_type(),
            KeyType::F32
        );
    }

    #[test]
    fn builder_options_and_validation() {
        let req = SortRequest::builder(vec![5u32, 2, 9])
            .payload(vec![50, 20, 90])
            .descending(true)
            .self_check(true)
            .tag("kv")
            .build()
            .unwrap();
        assert!(req.descending && req.self_check);
        assert_eq!(req.payload.as_deref(), Some(&[50u64, 20, 90][..]));
        assert_eq!(req.tag.as_deref(), Some("kv"));
        assert_eq!(req.deadline_ms, None);
        let with_deadline = SortRequest::builder(vec![1u32])
            .deadline_ms(250)
            .build()
            .unwrap();
        assert_eq!(with_deadline.deadline_ms, Some(250));
        // Mismatched payload is rejected at build time.
        let err = SortRequest::builder(vec![1u32, 2])
            .payload(vec![1])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("payload length"), "{err}");
    }

    #[test]
    fn job_data_reverse() {
        let mut job = JobData {
            keys: KeyData::from(vec![1u32, 2, 3]),
            payload: Some(vec![10, 20, 30]),
        };
        assert_eq!(job.len(), 3);
        assert!(!job.is_empty());
        job.reverse();
        assert_eq!(job.keys.as_u32().unwrap(), &[3, 2, 1]);
        assert_eq!(job.payload.as_deref(), Some(&[30u64, 20, 10][..]));
    }

    #[test]
    fn batch_accessors() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let b = Batch {
            requests: vec![PendingRequest {
                id: 1,
                request: SortRequest::new(vec![3u32, 2, 1]),
                admitted_at: Instant::now(),
                respond_to: tx,
            }],
            total_keys: 3,
        };
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
        assert_eq!(b.requests[0].len(), 3);
        assert!(!b.requests[0].is_empty());
    }
}

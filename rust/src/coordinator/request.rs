//! Request/response types of the sort service.

use crate::config::EngineKind;
use crate::Key;
use std::time::Instant;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// A sort job as submitted by a client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SortJob {
    /// The keys to sort.
    pub keys: Vec<Key>,
    /// Optional client-side tag echoed back in the response (workload
    /// name, tenant, …).
    pub tag: Option<String>,
}

impl SortJob {
    /// A job with no tag.
    pub fn new(keys: Vec<Key>) -> Self {
        SortJob { keys, tag: None }
    }

    /// A tagged job.
    pub fn tagged(keys: Vec<Key>, tag: impl Into<String>) -> Self {
        SortJob {
            keys,
            tag: Some(tag.into()),
        }
    }
}

/// A completed sort.
#[derive(Debug, Clone, PartialEq)]
pub struct SortOutcome {
    /// Request id assigned by the service.
    pub id: RequestId,
    /// The sorted keys.
    pub keys: Vec<Key>,
    /// Echoed job tag.
    pub tag: Option<String>,
    /// Which engine served it.
    pub engine: EngineKind,
    /// Index of the scheduler worker that executed the batch (0 for
    /// zero-key jobs, which never reach a worker).
    pub worker: usize,
    /// Requests that shared the engine dispatch with this one.
    pub batch_size: usize,
    /// Time spent queued before dispatch (ms).
    pub queue_ms: f64,
    /// Engine execution time for the whole batch (ms).
    pub service_ms: f64,
}

/// Internal: a job admitted to the queue, waiting for batch assembly.
#[derive(Debug)]
pub struct PendingRequest {
    /// Assigned id.
    pub id: RequestId,
    /// The job.
    pub job: SortJob,
    /// Admission timestamp (queue-delay accounting).
    pub admitted_at: Instant,
    /// Completion channel back to the caller (a one-shot: the service
    /// sends exactly one outcome).
    pub respond_to: std::sync::mpsc::Sender<crate::error::Result<SortOutcome>>,
}

impl PendingRequest {
    /// Key count of the job.
    pub fn len(&self) -> usize {
        self.job.keys.len()
    }

    /// True when the job carries no keys.
    pub fn is_empty(&self) -> bool {
        self.job.keys.is_empty()
    }
}

/// A group of requests dispatched to the engine together.
#[derive(Debug)]
pub struct Batch {
    /// The member requests, in admission order.
    pub requests: Vec<PendingRequest>,
    /// Σ key counts.
    pub total_keys: usize,
}

impl Batch {
    /// Number of member requests.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch holds no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_constructors() {
        let j = SortJob::new(vec![3, 1, 2]);
        assert!(j.tag.is_none());
        let t = SortJob::tagged(vec![1], "bench");
        assert_eq!(t.tag.as_deref(), Some("bench"));
    }

    #[test]
    fn batch_accessors() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let b = Batch {
            requests: vec![PendingRequest {
                id: 1,
                job: SortJob::new(vec![3, 2, 1]),
                admitted_at: Instant::now(),
                respond_to: tx,
            }],
            total_keys: 3,
        };
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
        assert_eq!(b.requests[0].len(), 3);
        assert!(!b.requests[0].is_empty());
    }
}

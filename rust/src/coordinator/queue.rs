//! The scheduler's **bounded dispatch queue**, extracted to a generic
//! structure so the loom models can exhaustively check its submit /
//! drain / shutdown orderings without dragging in engines, metrics or
//! response channels (`rust/tests/loom_models.rs`).
//!
//! Semantics (shared with the scheduler that wraps it):
//! * `capacity` bounds queued items; producers either bounce
//!   ([`BoundedQueue::try_push`]) or wait for a slot
//!   ([`BoundedQueue::push_blocking`]).
//! * Each consumer owns an *active slot*; [`BoundedQueue::pop`] marks
//!   it busy, [`BoundedQueue::finish`] frees it. Spare capacity means
//!   some consumer is neither busy nor promised a queued item.
//! * [`BoundedQueue::drain`] lets consumers finish the queue and then
//!   return `None` from `pop` — nothing accepted is ever dropped.
//! * A consumer that dies (engine panic) must call
//!   [`BoundedQueue::retire`] — the scheduler does this from a drop
//!   guard — so producers blocked on a dead pool wake up and get their
//!   item back instead of waiting forever.
//!
//! Two condvars signal the one state mutex: `work` towards consumers
//! (item arrived / drain started), `slots` towards producers (queue
//! shrank / consumer freed / consumer died). All waits re-check their
//! predicate under the lock, and every state change that can satisfy a
//! predicate notifies while the change and the check share the mutex —
//! the no-lost-wakeup discipline the loom model verifies.

use std::collections::VecDeque;

use crate::util::sync::{lock_unpoisoned, wait_unpoisoned, Condvar, Mutex};

/// Why a push did not go through; the item comes back intact.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity — retry after a slot-free wake-up.
    Full(T),
    /// Every consumer has retired; the item can never be served.
    Dead(T),
}

#[derive(Debug)]
struct QueueState<T> {
    queue: VecDeque<T>,
    /// `active[c]` = consumer `c` is processing an item.
    active: Vec<bool>,
    active_count: usize,
    /// Consumers still able to serve. Retirement wakes producers so
    /// nobody waits on a dead pool.
    live_consumers: usize,
    /// Set by [`BoundedQueue::drain`]: consumers empty the queue, then
    /// `pop` returns `None`.
    draining: bool,
}

/// A bounded MPMC queue with per-consumer busy slots. See module docs.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    /// Consumers wait here for items (or the drain signal).
    work: Condvar,
    /// Producers wait here for queue/consumer capacity.
    slots: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// Queue for `consumers` consumers holding at most `capacity`
    /// queued items.
    pub fn new(consumers: usize, capacity: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                active: vec![false; consumers],
                active_count: 0,
                live_consumers: consumers,
                draining: false,
            }),
            work: Condvar::new(),
            slots: Condvar::new(),
            capacity,
        }
    }

    /// Number of consumer slots (live or not).
    pub fn consumers(&self) -> usize {
        lock_unpoisoned(&self.state).active.len()
    }

    /// Consumers currently processing an item.
    pub fn active_count(&self) -> usize {
        lock_unpoisoned(&self.state).active_count
    }

    /// Consumers that have not retired.
    pub fn live_consumers(&self) -> usize {
        lock_unpoisoned(&self.state).live_consumers
    }

    /// True when an item pushed right now could start immediately:
    /// some consumer is neither busy nor already promised a queued
    /// item.
    pub fn has_spare_capacity(&self) -> bool {
        let st = lock_unpoisoned(&self.state);
        st.active_count + st.queue.len() < st.active.len()
    }

    /// Push without blocking. On success returns the queue depth just
    /// after the push (the scheduler's depth metrics want it); on
    /// failure hands the item back tagged with the reason.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = lock_unpoisoned(&self.state);
        if st.live_consumers == 0 {
            return Err(PushError::Dead(item));
        }
        if st.queue.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.queue.push_back(item);
        let depth = st.queue.len();
        self.work.notify_one();
        Ok(depth)
    }

    /// Push, waiting for capacity. Hands the item back only when every
    /// consumer has retired.
    pub fn push_blocking(&self, item: T) -> Result<usize, T> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if st.live_consumers == 0 {
                return Err(item);
            }
            if st.queue.len() < self.capacity {
                break;
            }
            st = wait_unpoisoned(&self.slots, st);
        }
        st.queue.push_back(item);
        let depth = st.queue.len();
        self.work.notify_one();
        Ok(depth)
    }

    /// Take the next item as consumer `consumer`, marking its slot
    /// busy; blocks while the queue is empty. Returns `None` once the
    /// queue is draining and empty (the consumer should exit).
    pub fn pop(&self, consumer: usize) -> Option<T> {
        let item = {
            let mut st = lock_unpoisoned(&self.state);
            loop {
                if let Some(item) = st.queue.pop_front() {
                    st.active[consumer] = true;
                    st.active_count += 1;
                    break item;
                }
                if st.draining {
                    return None;
                }
                st = wait_unpoisoned(&self.work, st);
            }
        };
        // The queue shrank: a producer blocked on capacity can move.
        self.slots.notify_all();
        Some(item)
    }

    /// Free consumer `consumer`'s busy slot after it finished an item.
    pub fn finish(&self, consumer: usize) {
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.active[consumer] {
                st.active[consumer] = false;
                st.active_count -= 1;
            }
        }
        self.slots.notify_all();
    }

    /// Permanently remove consumer `consumer` (normal exit or panic —
    /// the scheduler calls this from a drop guard). Frees its busy
    /// slot and wakes producers, so a dead pool bounces pushes instead
    /// of stranding them.
    pub fn retire(&self, consumer: usize) {
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.active[consumer] {
                st.active[consumer] = false;
                st.active_count -= 1;
            }
            st.live_consumers = st.live_consumers.saturating_sub(1);
        }
        self.slots.notify_all();
    }

    /// Start draining: consumers finish every queued item, then `pop`
    /// returns `None`.
    pub fn drain(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.draining = true;
        self.work.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_through_one_consumer() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1, 4);
        assert_eq!(q.try_push(1).expect("push 1"), 1);
        assert_eq!(q.try_push(2).expect("push 2"), 2);
        assert_eq!(q.pop(0), Some(1));
        q.finish(0);
        assert_eq!(q.pop(0), Some(2));
        q.finish(0);
        q.drain();
        assert_eq!(q.pop(0), None);
    }

    #[test]
    fn bounces_when_full_and_after_death() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1, 1);
        q.try_push(1).expect("first fits");
        match q.try_push(2) {
            Err(PushError::Full(item)) => assert_eq!(item, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        q.retire(0);
        match q.try_push(3) {
            Err(PushError::Dead(item)) => assert_eq!(item, 3),
            other => panic!("expected Dead, got {other:?}"),
        }
        assert!(q.push_blocking(4).is_err());
    }

    #[test]
    fn spare_capacity_tracks_active_and_queued() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2, 4);
        assert!(q.has_spare_capacity());
        q.try_push(1).expect("push");
        // One consumer busy, one idle: still spare.
        assert_eq!(q.pop(0), Some(1));
        assert!(q.has_spare_capacity());
        assert_eq!(q.active_count(), 1);
        // Second consumer busy too: no spare.
        q.try_push(2).expect("push");
        assert_eq!(q.pop(1), Some(2));
        assert!(!q.has_spare_capacity());
        q.finish(0);
        assert!(q.has_spare_capacity());
    }

    #[test]
    fn drain_lets_consumers_exit_across_threads() {
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(2, 4));
        let consumers: Vec<_> = (0..2)
            .map(|c| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut served = 0u32;
                    while let Some(_item) = q.pop(c) {
                        served += 1;
                        q.finish(c);
                    }
                    q.retire(c);
                    served
                })
            })
            .collect();
        for i in 0..8 {
            q.push_blocking(i).expect("live consumers");
        }
        q.drain();
        let served: u32 = consumers
            .into_iter()
            .map(|h| h.join().expect("consumer thread"))
            .sum();
        assert_eq!(served, 8);
    }
}

//! Configuration system: one config drives the service, the CLI and the
//! experiment harness. Loadable from JSON files (via the in-tree
//! [`crate::util::json`] module), overridable from the command line.
//! Unknown fields are rejected; missing fields fall back to defaults, so
//! partial configs stay forward-compatible.

use crate::algos::bucket_sort::BucketSortParams;
use crate::algos::KernelKind;
use crate::error::{Error, Result};
use crate::exec::NativeParams;
use crate::sim::{DevicePool, GpuModel};
use crate::util::Json;
use std::path::Path;

/// Which engine the coordinator serves requests with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Native multicore engine (real performance path).
    #[default]
    Native,
    /// Simulated-GPU engine: executes Algorithm 1 on the host while
    /// modelling a Table-1 device (traffic ledger + capacity limits).
    Sim,
    /// PJRT engine: runs the AOT-compiled JAX/Pallas pipeline through
    /// the XLA CPU client (fixed shapes from the artifact manifest).
    Pjrt,
    /// Sharded multi-device engine: Algorithm 1 per device across a
    /// pool of simulated GPUs with a deterministic cross-device
    /// combine — sorts beyond any single device's memory ceiling.
    Sharded,
}

impl EngineKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "sim" | "simulated" => Some(EngineKind::Sim),
            "pjrt" | "xla" => Some(EngineKind::Pjrt),
            "sharded" | "multigpu" | "pool" => Some(EngineKind::Sharded),
            _ => None,
        }
    }

    /// Stable config-file name.
    pub fn id(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Sim => "sim",
            EngineKind::Pjrt => "pjrt",
            EngineKind::Sharded => "sharded",
        }
    }
}

/// Dynamic batcher settings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchConfig {
    /// Maximum keys merged into one engine pass.
    pub max_batch_keys: usize,
    /// Maximum requests merged into one batch.
    pub max_batch_requests: usize,
    /// How long an under-full batch may wait for company (ms).
    pub max_wait_ms: u64,
    /// Queue depth before backpressure rejections kick in.
    pub queue_capacity: usize,
    /// Total queued keys before backpressure (memory budget proxy).
    pub max_queued_keys: usize,
    /// Coalesced dispatch: requests of at most this many keys that
    /// share a batch, key type and payload shape are composed into ONE
    /// segment-tagged kernel invocation (split back into byte-identical
    /// per-request responses). `0` disables coalescing. See
    /// [`crate::coordinator::coalesce`].
    pub coalesce_max_keys: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch_keys: 1 << 22,
            max_batch_requests: 64,
            max_wait_ms: 2,
            queue_capacity: 1024,
            max_queued_keys: 1 << 27,
            coalesce_max_keys: 1 << 17,
        }
    }
}

/// Network-tier settings (the TCP server/client in [`crate::net`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetConfig {
    /// Hard ceiling on a single frame's payload, in bytes. Frames that
    /// declare more are rejected *before* any allocation — the decoder's
    /// defense against hostile length prefixes.
    pub max_frame_len: usize,
    /// Per-connection credit window: how many sort requests one
    /// connection may have in flight (streaming or queued) at once.
    /// Credits are granted in the handshake and replenished as
    /// responses/sheds complete — equal windows give per-connection
    /// fairness.
    pub credits: usize,
    /// Preferred chunk size (bytes of key/payload data per streaming
    /// frame). Must fit `max_frame_len`.
    pub chunk_bytes: usize,
    /// Hard per-request key-count ceiling; larger submissions are shed
    /// with a typed `TooLarge` error frame at `SortBegin`, before any
    /// key bytes are buffered.
    pub max_request_keys: usize,
    /// How long a graceful drain waits for in-flight sorts before
    /// giving up and closing sockets anyway, in milliseconds. Also
    /// bounds how long a cluster node waits for the registry to ack
    /// its deregister on shutdown.
    pub drain_timeout_ms: u64,
    /// Capacity of the per-`(session, request id)` idempotency window
    /// of completed responses (replayed to reconnecting clients
    /// instead of re-executing). `0` disables caching; evictions under
    /// pressure are counted as `net_dedup_evictions`.
    pub dedup_window: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_frame_len: 1 << 20,
            credits: 8,
            chunk_bytes: 1 << 18,
            max_request_keys: 1 << 26,
            drain_timeout_ms: 60_000,
            dedup_window: 256,
        }
    }
}

impl NetConfig {
    /// Sanity-check the combination.
    pub fn validate(&self) -> Result<()> {
        if self.max_frame_len < 1024 {
            return Err(Error::Config(
                "net.max_frame_len must be at least 1024 bytes".into(),
            ));
        }
        if self.credits == 0 {
            return Err(Error::Config("net.credits must be at least 1".into()));
        }
        if self.chunk_bytes < 8 || self.chunk_bytes > self.max_frame_len {
            return Err(Error::Config(format!(
                "net.chunk_bytes must be in [8, max_frame_len = {}]",
                self.max_frame_len
            )));
        }
        if self.max_request_keys == 0 {
            return Err(Error::Config(
                "net.max_request_keys must be positive".into(),
            ));
        }
        if self.drain_timeout_ms == 0 {
            return Err(Error::Config(
                "net.drain_timeout_ms must be at least 1 (use a large value, not 0, to wait long)"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Top-level service configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Engine selection.
    pub engine: EngineKind,
    /// Scheduler worker count: how many engine instances execute batches
    /// concurrently. Each worker owns its own engine (for
    /// [`EngineKind::Sharded`], its own disjoint device lease — so
    /// `workers` must not exceed `devices.len()` there).
    pub workers: usize,
    /// Simulated device (for [`EngineKind::Sim`]).
    pub device: GpuModel,
    /// Simulated device pool (for [`EngineKind::Sharded`]); must be
    /// non-empty.
    pub devices: Vec<GpuModel>,
    /// Algorithm-1 parameters (tile, s).
    pub sort: BucketSortParams,
    /// Executed tile/bucket kernel for every engine's hot path
    /// (`adaptive` by default — the cost-model front-end picks per
    /// request; `radix` / `bitonic` pin a static kernel. Outputs are
    /// byte-identical in every case, see [`KernelKind`]).
    pub kernel: KernelKind,
    /// Path to a calibrated cost-model JSON for the adaptive front-end
    /// (`""` = the built-in defaults; see
    /// [`crate::algos::adaptive::CostModel`]). Exposed as
    /// `--cost-model`.
    pub cost_model: String,
    /// Path to a deterministic fault-injection plan JSON (`""` = no
    /// injection, the production default; see
    /// [`crate::sim::FaultPlan`]). Exposed as `--fault-plan`.
    pub fault_plan: String,
    /// Digit width of the planned radix kernel, in bits (1–16; default
    /// 11 → 2048 counting bins, ⌈32/11⌉ = 3 passes over u32 keys).
    /// Exposed as `--digit-bits`; wall time only, never bytes.
    pub digit_bits: u32,
    /// Native engine parameters.
    pub native: NativeParams,
    /// Batcher parameters.
    pub batch: BatchConfig,
    /// Network-tier parameters (`gbs serve --listen` / `--connect`).
    pub net: NetConfig,
    /// Verify every response is a sorted permutation (costly; tests and
    /// debugging).
    pub verify: bool,
    /// Artifact directory for the PJRT engine.
    pub artifacts_dir: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            engine: EngineKind::Native,
            workers: 1,
            device: GpuModel::Gtx285_2G,
            devices: DevicePool::DEFAULT_DEVICES.to_vec(),
            sort: BucketSortParams::default(),
            kernel: KernelKind::default(),
            cost_model: String::new(),
            fault_plan: String::new(),
            digit_bits: crate::algos::plan::DEFAULT_DIGIT_BITS,
            native: NativeParams::default(),
            batch: BatchConfig::default(),
            net: NetConfig::default(),
            verify: false,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl ServiceConfig {
    /// Load from a JSON file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Config(format!("{}: {e}", path.as_ref().display())))?;
        Self::from_json(&text)
    }

    /// Parse from JSON text; missing fields default, unknown fields
    /// error.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let mut cfg = ServiceConfig::default();
        let Json::Obj(pairs) = &v else {
            return Err(Error::Config("config must be a JSON object".into()));
        };
        for (key, val) in pairs {
            match key.as_str() {
                "engine" => {
                    let s = str_field(val, "engine")?;
                    cfg.engine = EngineKind::parse(&s)
                        .ok_or_else(|| Error::Config(format!("unknown engine {s:?}")))?;
                }
                "workers" => {
                    cfg.workers = val
                        .as_usize()
                        .ok_or_else(|| Error::Config("workers must be an integer".into()))?;
                }
                "device" => {
                    let s = str_field(val, "device")?;
                    cfg.device = GpuModel::parse(&s)
                        .ok_or_else(|| Error::Config(format!("unknown device {s:?}")))?;
                }
                "devices" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| Error::Config("devices must be an array".into()))?;
                    cfg.devices = arr
                        .iter()
                        .map(|v| {
                            let s = v
                                .as_str()
                                .ok_or_else(|| Error::Config("devices entries must be strings".into()))?;
                            GpuModel::parse(s)
                                .ok_or_else(|| Error::Config(format!("unknown device {s:?}")))
                        })
                        .collect::<Result<Vec<_>>>()?;
                }
                "sort" => {
                    cfg.sort = BucketSortParams {
                        tile: usize_field(val, "tile").unwrap_or(cfg.sort.tile),
                        s: usize_field(val, "s").unwrap_or(cfg.sort.s),
                    };
                }
                "kernel" => {
                    let s = str_field(val, "kernel")?;
                    cfg.kernel = KernelKind::parse(&s)
                        .ok_or_else(|| Error::Config(format!("unknown kernel {s:?}")))?;
                }
                "cost_model" => {
                    cfg.cost_model = str_field(val, "cost_model")?;
                }
                "fault_plan" => {
                    cfg.fault_plan = str_field(val, "fault_plan")?;
                }
                "digit_bits" => {
                    let v = val
                        .as_usize()
                        .ok_or_else(|| Error::Config("digit_bits must be an integer".into()))?;
                    cfg.digit_bits = u32::try_from(v)
                        .map_err(|_| Error::Config(format!("digit_bits out of range: {v}")))?;
                }
                "native" => {
                    cfg.native = NativeParams {
                        workers: usize_field(val, "workers").unwrap_or(cfg.native.workers),
                        samples_per_chunk: usize_field(val, "samples_per_chunk")
                            .unwrap_or(cfg.native.samples_per_chunk),
                        bucket_factor: usize_field(val, "bucket_factor")
                            .unwrap_or(cfg.native.bucket_factor),
                        sequential_cutoff: usize_field(val, "sequential_cutoff")
                            .unwrap_or(cfg.native.sequential_cutoff),
                    };
                }
                "batch" => {
                    cfg.batch = BatchConfig {
                        max_batch_keys: usize_field(val, "max_batch_keys")
                            .unwrap_or(cfg.batch.max_batch_keys),
                        max_batch_requests: usize_field(val, "max_batch_requests")
                            .unwrap_or(cfg.batch.max_batch_requests),
                        max_wait_ms: usize_field(val, "max_wait_ms")
                            .map(|v| v as u64)
                            .unwrap_or(cfg.batch.max_wait_ms),
                        queue_capacity: usize_field(val, "queue_capacity")
                            .unwrap_or(cfg.batch.queue_capacity),
                        max_queued_keys: usize_field(val, "max_queued_keys")
                            .unwrap_or(cfg.batch.max_queued_keys),
                        coalesce_max_keys: usize_field(val, "coalesce_max_keys")
                            .unwrap_or(cfg.batch.coalesce_max_keys),
                    };
                }
                "net" => {
                    cfg.net = NetConfig {
                        max_frame_len: usize_field(val, "max_frame_len")
                            .unwrap_or(cfg.net.max_frame_len),
                        credits: usize_field(val, "credits").unwrap_or(cfg.net.credits),
                        chunk_bytes: usize_field(val, "chunk_bytes")
                            .unwrap_or(cfg.net.chunk_bytes),
                        max_request_keys: usize_field(val, "max_request_keys")
                            .unwrap_or(cfg.net.max_request_keys),
                        drain_timeout_ms: usize_field(val, "drain_timeout_ms")
                            .map(|v| v as u64)
                            .unwrap_or(cfg.net.drain_timeout_ms),
                        dedup_window: usize_field(val, "dedup_window")
                            .unwrap_or(cfg.net.dedup_window),
                    };
                }
                "verify" => {
                    cfg.verify = val
                        .as_bool()
                        .ok_or_else(|| Error::Config("verify must be a bool".into()))?;
                }
                "artifacts_dir" => {
                    cfg.artifacts_dir = str_field(val, "artifacts_dir")?;
                }
                other => {
                    return Err(Error::Config(format!("unknown config field {other:?}")));
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the combination.
    pub fn validate(&self) -> Result<()> {
        self.sort.validate()?;
        self.net.validate()?;
        crate::algos::plan::validate_digit_bits(self.digit_bits)?;
        // A configured cost-model file must load (exist, parse, carry
        // the right version) — fail at config time, not mid-request.
        crate::algos::adaptive::CostModel::resolve(&self.cost_model)?;
        // Same discipline for a configured fault plan: it must exist,
        // parse, and carry a supported version before any request runs.
        crate::sim::FaultPlan::resolve(&self.fault_plan)?;
        if self.workers == 0 {
            return Err(Error::Config("workers must be at least 1".into()));
        }
        if self.devices.is_empty() {
            return Err(Error::Config("devices must not be empty".into()));
        }
        if self.engine == EngineKind::Sharded && self.workers > self.devices.len() {
            return Err(Error::Config(format!(
                "sharded engine: {} workers need {} devices but only {} are configured \
                 (each worker leases a disjoint device subset)",
                self.workers,
                self.workers,
                self.devices.len()
            )));
        }
        if self.batch.max_batch_keys == 0 || self.batch.queue_capacity == 0 {
            return Err(Error::Config(
                "batch.max_batch_keys and batch.queue_capacity must be positive".into(),
            ));
        }
        if self.batch.max_batch_requests == 0 {
            return Err(Error::Config(
                "batch.max_batch_requests must be positive".into(),
            ));
        }
        Ok(())
    }

    /// Serialize to pretty JSON (for `gbs config --print`).
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("engine", Json::str(self.engine.id())),
            ("workers", Json::num(self.workers as f64)),
            ("device", Json::str(self.device.id())),
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| Json::str(d.id())).collect()),
            ),
            (
                "sort",
                Json::obj(vec![
                    ("tile", Json::num(self.sort.tile as f64)),
                    ("s", Json::num(self.sort.s as f64)),
                ]),
            ),
            ("kernel", Json::str(self.kernel.id())),
            ("cost_model", Json::str(self.cost_model.clone())),
            ("fault_plan", Json::str(self.fault_plan.clone())),
            ("digit_bits", Json::num(self.digit_bits as f64)),
            (
                "native",
                Json::obj(vec![
                    ("workers", Json::num(self.native.workers as f64)),
                    (
                        "samples_per_chunk",
                        Json::num(self.native.samples_per_chunk as f64),
                    ),
                    ("bucket_factor", Json::num(self.native.bucket_factor as f64)),
                    (
                        "sequential_cutoff",
                        Json::num(self.native.sequential_cutoff as f64),
                    ),
                ]),
            ),
            (
                "batch",
                Json::obj(vec![
                    ("max_batch_keys", Json::num(self.batch.max_batch_keys as f64)),
                    (
                        "max_batch_requests",
                        Json::num(self.batch.max_batch_requests as f64),
                    ),
                    ("max_wait_ms", Json::num(self.batch.max_wait_ms as f64)),
                    ("queue_capacity", Json::num(self.batch.queue_capacity as f64)),
                    (
                        "max_queued_keys",
                        Json::num(self.batch.max_queued_keys as f64),
                    ),
                    (
                        "coalesce_max_keys",
                        Json::num(self.batch.coalesce_max_keys as f64),
                    ),
                ]),
            ),
            (
                "net",
                Json::obj(vec![
                    ("max_frame_len", Json::num(self.net.max_frame_len as f64)),
                    ("credits", Json::num(self.net.credits as f64)),
                    ("chunk_bytes", Json::num(self.net.chunk_bytes as f64)),
                    (
                        "max_request_keys",
                        Json::num(self.net.max_request_keys as f64),
                    ),
                    (
                        "drain_timeout_ms",
                        Json::num(self.net.drain_timeout_ms as f64),
                    ),
                    ("dedup_window", Json::num(self.net.dedup_window as f64)),
                ]),
            ),
            ("verify", Json::Bool(self.verify)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
        ])
        .to_string_pretty()
    }
}

fn str_field(v: &Json, name: &str) -> Result<String> {
    v.as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Config(format!("{name} must be a string")))
}

fn usize_field(obj: &Json, key: &str) -> Option<usize> {
    obj.get(key).and_then(|v| v.as_usize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServiceConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sim,
            device: GpuModel::Gtx260,
            devices: vec![GpuModel::TeslaC1060, GpuModel::Gtx260],
            verify: true,
            ..Default::default()
        };
        let json = cfg.to_json();
        let back = ServiceConfig::from_json(&json).unwrap();
        assert_eq!(cfg, back);
        // All four devices round-trip.
        for device in GpuModel::ALL {
            let c = ServiceConfig {
                device,
                ..Default::default()
            };
            assert_eq!(ServiceConfig::from_json(&c.to_json()).unwrap(), c);
        }
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg = ServiceConfig::from_json(r#"{"engine":"sim"}"#).unwrap();
        assert_eq!(cfg.engine, EngineKind::Sim);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.sort, BucketSortParams::default());
        assert_eq!(cfg.batch, BatchConfig::default());
        assert_eq!(cfg.kernel, KernelKind::Adaptive);
        assert_eq!(cfg.cost_model, "");
    }

    #[test]
    fn kernel_field_roundtrips_and_validates() {
        let cfg = ServiceConfig::from_json(r#"{"kernel":"bitonic"}"#).unwrap();
        assert_eq!(cfg.kernel, KernelKind::Bitonic);
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        let auto = ServiceConfig::from_json(r#"{"kernel":"auto"}"#).unwrap();
        assert_eq!(auto.kernel, KernelKind::Adaptive);
        assert!(ServiceConfig::from_json(r#"{"kernel":"quick"}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"kernel":3}"#).is_err());
    }

    #[test]
    fn cost_model_field_roundtrips_and_validates() {
        // Empty path (the default) round-trips and means built-ins.
        let cfg = ServiceConfig::from_json(r#"{"cost_model":""}"#).unwrap();
        assert_eq!(cfg.cost_model, "");
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // A missing file is rejected at config time.
        assert!(
            ServiceConfig::from_json(r#"{"cost_model":"/nonexistent/model.json"}"#).is_err()
        );
        // A valid calibration file is accepted and round-trips.
        let dir = std::env::temp_dir().join(format!("gbs_cm_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.json");
        std::fs::write(&p, crate::algos::adaptive::CostModel::default().to_json().to_string_pretty())
            .unwrap();
        let loaded =
            ServiceConfig::from_json(&format!(r#"{{"cost_model":"{}"}}"#, p.display()))
                .unwrap();
        assert_eq!(loaded.cost_model, p.display().to_string());
        assert_eq!(ServiceConfig::from_json(&loaded.to_json()).unwrap(), loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_plan_field_roundtrips_and_validates() {
        // Empty path (the default) round-trips and means no injection.
        let cfg = ServiceConfig::from_json(r#"{"fault_plan":""}"#).unwrap();
        assert_eq!(cfg.fault_plan, "");
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // A missing file is rejected at config time.
        assert!(
            ServiceConfig::from_json(r#"{"fault_plan":"/nonexistent/plan.json"}"#).is_err()
        );
        // A valid plan file is accepted and round-trips.
        let dir = std::env::temp_dir().join(format!("gbs_fp_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("plan.json");
        std::fs::write(
            &p,
            r#"{"version":1,"seed":3,"rules":[{"point":"device_lost","target":0}]}"#,
        )
        .unwrap();
        let loaded =
            ServiceConfig::from_json(&format!(r#"{{"fault_plan":"{}"}}"#, p.display()))
                .unwrap();
        assert_eq!(loaded.fault_plan, p.display().to_string());
        assert_eq!(ServiceConfig::from_json(&loaded.to_json()).unwrap(), loaded);
        // A plan that fails validation (bad version) is rejected.
        std::fs::write(&p, r#"{"version":2,"rules":[]}"#).unwrap();
        assert!(
            ServiceConfig::from_json(&format!(r#"{{"fault_plan":"{}"}}"#, p.display()))
                .is_err()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn digit_bits_field_roundtrips_and_validates() {
        let cfg = ServiceConfig::from_json(r#"{"digit_bits":13}"#).unwrap();
        assert_eq!(cfg.digit_bits, 13);
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // Default is the planner's 11-bit digit.
        assert_eq!(
            ServiceConfig::default().digit_bits,
            crate::algos::plan::DEFAULT_DIGIT_BITS
        );
        // Out-of-range widths and non-integers are rejected.
        assert!(ServiceConfig::from_json(r#"{"digit_bits":0}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"digit_bits":17}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"digit_bits":"wide"}"#).is_err());
    }

    #[test]
    fn coalesce_field_roundtrips() {
        let cfg =
            ServiceConfig::from_json(r#"{"batch":{"coalesce_max_keys":0}}"#).unwrap();
        assert_eq!(cfg.batch.coalesce_max_keys, 0, "0 disables coalescing");
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        assert_eq!(BatchConfig::default().coalesce_max_keys, 1 << 17);
    }

    #[test]
    fn net_field_roundtrips_and_validates() {
        let cfg = ServiceConfig::from_json(
            r#"{"net":{"max_frame_len":65536,"credits":4,"chunk_bytes":4096,"max_request_keys":1000000,"drain_timeout_ms":2500,"dedup_window":32}}"#,
        )
        .unwrap();
        assert_eq!(cfg.net.max_frame_len, 65536);
        assert_eq!(cfg.net.credits, 4);
        assert_eq!(cfg.net.chunk_bytes, 4096);
        assert_eq!(cfg.net.max_request_keys, 1_000_000);
        assert_eq!(cfg.net.drain_timeout_ms, 2500);
        assert_eq!(cfg.net.dedup_window, 32);
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // Partial net objects keep defaults for the rest.
        let partial = ServiceConfig::from_json(r#"{"net":{"credits":2}}"#).unwrap();
        assert_eq!(partial.net.credits, 2);
        assert_eq!(partial.net.max_frame_len, NetConfig::default().max_frame_len);
        assert_eq!(partial.net.drain_timeout_ms, 60_000);
        assert_eq!(partial.net.dedup_window, 256);
        // dedup_window 0 is valid (caching off); drain_timeout_ms 0 is not.
        assert!(ServiceConfig::from_json(r#"{"net":{"dedup_window":0}}"#).is_ok());
        assert!(ServiceConfig::from_json(r#"{"net":{"drain_timeout_ms":0}}"#).is_err());
        // Invalid combinations are rejected.
        assert!(ServiceConfig::from_json(r#"{"net":{"credits":0}}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"net":{"max_frame_len":16}}"#).is_err());
        assert!(
            ServiceConfig::from_json(r#"{"net":{"chunk_bytes":2097152}}"#).is_err(),
            "chunk larger than max_frame_len must be rejected"
        );
        assert!(ServiceConfig::from_json(r#"{"net":{"max_request_keys":0}}"#).is_err());
        NetConfig::default().validate().unwrap();
    }

    #[test]
    fn workers_field_roundtrips_and_validates() {
        let cfg = ServiceConfig::from_json(r#"{"workers":4}"#).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(ServiceConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // Zero workers is invalid.
        assert!(ServiceConfig::from_json(r#"{"workers":0}"#).is_err());
        // Sharded: workers are capped by the device count (disjoint
        // per-worker leases).
        assert!(ServiceConfig::from_json(r#"{"engine":"sharded","workers":4}"#).is_ok());
        let err = ServiceConfig::from_json(r#"{"engine":"sharded","workers":5}"#).unwrap_err();
        assert!(err.to_string().contains("devices"), "{err}");
        assert!(ServiceConfig::from_json(
            r#"{"engine":"sharded","workers":2,"devices":["tesla","gtx260"]}"#
        )
        .is_ok());
        // Native engines have no such cap.
        assert!(ServiceConfig::from_json(r#"{"workers":32}"#).is_ok());
    }

    #[test]
    fn file_loading() {
        let dir = std::env::temp_dir().join(format!("gbs_cfg_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"engine":"pjrt","verify":true}"#).unwrap();
        let cfg = ServiceConfig::from_file(&p).unwrap();
        assert_eq!(cfg.engine, EngineKind::Pjrt);
        assert!(cfg.verify);
        assert!(ServiceConfig::from_file(dir.join("missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn invalid_configs_rejected() {
        // Bad sort params.
        assert!(ServiceConfig::from_json(r#"{"sort":{"tile":100,"s":3}}"#).is_err());
        // Zero batch budget.
        assert!(
            ServiceConfig::from_json(r#"{"batch":{"max_batch_keys":0}}"#).is_err()
        );
        // Unknown field.
        let err = ServiceConfig::from_json(r#"{"engin":"sim"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown config field"));
        // Unknown engine/device.
        assert!(ServiceConfig::from_json(r#"{"engine":"gpu"}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"device":"fermi"}"#).is_err());
        // Bad device pools.
        assert!(ServiceConfig::from_json(r#"{"devices":[]}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"devices":["fermi"]}"#).is_err());
        assert!(ServiceConfig::from_json(r#"{"devices":"tesla"}"#).is_err());
        // Not an object.
        assert!(ServiceConfig::from_json("[1,2]").is_err());
    }

    #[test]
    fn engine_parse() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("SIM"), Some(EngineKind::Sim));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("sharded"), Some(EngineKind::Sharded));
        assert_eq!(EngineKind::parse("multigpu"), Some(EngineKind::Sharded));
        assert_eq!(EngineKind::parse("gpu"), None);
        for k in [
            EngineKind::Native,
            EngineKind::Sim,
            EngineKind::Pjrt,
            EngineKind::Sharded,
        ] {
            assert_eq!(EngineKind::parse(k.id()), Some(k));
        }
    }

    #[test]
    fn device_pool_parsing() {
        let cfg =
            ServiceConfig::from_json(r#"{"engine":"sharded","devices":["tesla","gtx260"]}"#)
                .unwrap();
        assert_eq!(cfg.engine, EngineKind::Sharded);
        assert_eq!(cfg.devices, vec![GpuModel::TeslaC1060, GpuModel::Gtx260]);
        // Default pool is the four heterogeneous Table 1 devices.
        let d = ServiceConfig::default();
        assert_eq!(d.devices.len(), 4);
    }
}

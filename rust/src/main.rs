//! `gbs` — the GPU Bucket Sort launcher.
//!
//! ```text
//! gbs sort        one-shot sort (native / sim / pjrt engine, any algorithm)
//! gbs serve       run the batched sort service under a synthetic load
//! gbs registry    run the cluster membership registry
//! gbs experiment  regenerate the paper's tables and figures (CSV + console)
//! gbs specs       print Table 1
//! gbs config      print or validate a service config
//! gbs artifacts   validate the AOT artifact set end-to-end
//! ```
//!
//! Argument parsing is hand-rolled (the build is offline — no clap);
//! every flag is `--name value`.

use gpu_bucket_sort::algos::sharded::{ShardedSort, ShardedSortParams};
use gpu_bucket_sort::algos::Algorithm;
use gpu_bucket_sort::config::{EngineKind, NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{
    build_engine_with_faults, verify_outcome, JobData, SortRequest, SortService,
};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::net::{
    registry, ClusterClient, ClusterOptions, NetClient, NetServer, NodeRegistration, Registry,
    RegistryConfig,
};
use gpu_bucket_sort::runtime::PjrtRuntime;
use gpu_bucket_sort::sim::{DevicePool, GpuModel, GpuSim};
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{is_sorted_permutation, ExecContext, Key, KernelKind, KeyType};
use std::collections::HashMap;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "sort" => cmd_sort(&flags),
        "serve" => cmd_serve(&flags),
        "registry" => cmd_registry(&flags),
        "experiment" | "exp" => cmd_experiment(&flags),
        "specs" => {
            println!("{}", exp::table1().to_markdown());
            Ok(())
        }
        "config" => cmd_config(&flags),
        "artifacts" => cmd_artifacts(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command {other:?} (try `gbs help`)")),
    }
}

fn print_usage() {
    // Built from canonical_name() so help and parse() cannot drift.
    let algos = Algorithm::ALL.map(Algorithm::canonical_name).join("|");
    println!(
        "gbs — Deterministic Sample Sort for GPUs (Dehne & Zaboli 2010) reproduction

USAGE: gbs <command> [--flag value ...]

COMMANDS
  sort        --n 32M [--dist uniform] [--algo {algos}]
              [--engine native|sim|pjrt|sharded] [--device gtx285]
              [--devices gtx285,tesla,gtx285-1g,gtx260] [--seed 1]
              [--kernel adaptive|radix|bitonic] [--digit-bits 11]
              [--cost-model configs/cost_model.json]
              [--fault-plan configs/fault_plan.json]
              [--key-type u32|u64|i32|i64|f32] [--payload true]
              [--descending true] [--verify true] [--analytic true]
              (sharded: shard across a multi-GPU pool; --analytic prices
               paper-scale n, e.g. 768M over 4 devices, without data;
               --kernel picks the executed kernel — adaptive (default)
               profiles each request and picks radix, comparison or a
               sorted/reverse early exit via the cost model loaded from
               --cost-model (built-in defaults when omitted); radix and
               bitonic pin a static kernel, the latter the paper's
               comparison path — outputs byte-identical in every case;
               --digit-bits sets the
               planned radix kernel's digit width (1–16, default 11 →
               3 passes over u32) — wall time only, never bytes;
               --key-type/--payload/--descending route through the typed
               engine path — f32 sorts by IEEE-754 total order, NaN-safe;
               --connect HOST:PORT submits the sort to a remote
               `gbs serve --listen` server over the framed TCP protocol,
               with [--connections 1] pooled sockets — add --drain true
               to ask that server to drain gracefully instead;
               --registry HOST:PORT instead resolves the node set from a
               `gbs registry` process and routes to the least-loaded
               node, failing over to survivors on node death — with
               --drain true it asks the *registry* to drain)
  serve       [--requests 64] [--concurrency 8] [--n 1M] [--dist uniform]
              [--engine native|sharded] [--workers 4] [--config file.json]
              [--kernel adaptive|radix|bitonic] [--digit-bits 11]
              [--cost-model configs/cost_model.json]
              [--fault-plan configs/fault_plan.json]
              [--coalesce-max-keys 128K]
              [--key-type u32] [--payload true] [--descending true]
              [--listen 127.0.0.1:4750] [--registry HOST:PORT]
              [--advertise HOST:PORT] [--drain-timeout-ms 60000]
              (--workers runs N engine instances concurrently; sharded
               engines lease disjoint device subsets per worker;
               small same-shaped requests coalesce into one kernel
               invocation up to --coalesce-max-keys each, 0 disables;
               --listen serves sorts over TCP instead of running the
               synthetic load — port 0 picks a free port — until a
               client requests a drain; --registry self-registers the
               node with a cluster registry and heartbeats until
               shutdown, which deregisters *before* draining —
               --advertise overrides the address published to the
               registry, --drain-timeout-ms bounds the drain wait)
  registry    [--listen 127.0.0.1:0] [--heartbeat-ms 100]
              [--suspect-misses 3] [--evict-misses 6]
              (lease-based cluster membership: nodes register and
               heartbeat; a node that misses --suspect-misses beats is
               withheld from routing, one that misses --evict-misses is
               evicted — stop with `gbs sort --registry ADDR --drain true`)
  experiment  <table1|fig3|fig4|fig5|fig6|fig7|robustness|rates|sharded|all>
              [--out results] [--fast true]
  specs       print the paper's Table 1
  config      [--file cfg.json] — print the (default or loaded) config
  artifacts   [--dir artifacts] — load, compile and smoke-run every artifact"
    );
}

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let value = args
                .get(i + 1)
                .ok_or_else(|| format!("flag --{name} needs a value"))?;
            flags.insert(name.to_string(), value.clone());
            i += 2;
        } else {
            // bare word (subcommand argument)
            flags.entry("_arg".into()).or_insert_with(|| a.clone());
            i += 1;
        }
    }
    Ok(flags)
}

/// Parse "32M", "512K", "1000000".
fn parse_size(s: &str) -> Result<usize, String> {
    let s = s.trim();
    let (num, mult) = if let Some(p) = s.strip_suffix(['M', 'm']) {
        (p, 1usize << 20)
    } else if let Some(p) = s.strip_suffix(['K', 'k']) {
        (p, 1usize << 10)
    } else {
        (s, 1)
    };
    num.parse::<usize>()
        .map(|v| v * mult)
        .map_err(|e| format!("bad size {s:?}: {e}"))
}

fn flag<'a>(flags: &'a HashMap<String, String>, name: &str, default: &'a str) -> &'a str {
    flags.get(name).map(String::as_str).unwrap_or(default)
}

fn cmd_sort(flags: &HashMap<String, String>) -> Result<(), String> {
    let n = parse_size(flag(flags, "n", "1M"))?;
    let dist = Distribution::parse(flag(flags, "dist", "uniform"))
        .ok_or("unknown distribution")?;
    let seed: u64 = flag(flags, "seed", "1").parse().map_err(|e| format!("{e}"))?;
    let engine = EngineKind::parse(flag(flags, "engine", "native")).ok_or("unknown engine")?;
    let verify = flag(flags, "verify", "true") == "true";
    let analytic = flag(flags, "analytic", "false") == "true";
    let key_type = KeyType::parse(flag(flags, "key-type", "u32")).ok_or("unknown key type")?;
    let payload = flag(flags, "payload", "false") == "true";
    let descending = flag(flags, "descending", "false") == "true";
    if let Some(addr) = flags.get("connect") {
        if analytic {
            return Err("--analytic runs locally; it cannot combine with --connect".into());
        }
        return cmd_sort_remote(
            flags, n, dist, seed, verify, key_type, payload, descending, addr,
        );
    }
    if let Some(reg_addr) = flags.get("registry") {
        if analytic {
            return Err("--analytic runs locally; it cannot combine with --registry".into());
        }
        return cmd_sort_cluster(
            flags, n, dist, seed, verify, key_type, payload, descending, reg_addr,
        );
    }
    let kernel = KernelKind::parse(flag(flags, "kernel", KernelKind::default().id()))
        .ok_or("unknown kernel")?;
    let digit_bits: u32 = flag(
        flags,
        "digit-bits",
        &gpu_bucket_sort::algos::plan::DEFAULT_DIGIT_BITS.to_string(),
    )
    .parse()
    .map_err(|e| format!("bad --digit-bits: {e}"))?;
    gpu_bucket_sort::algos::plan::validate_digit_bits(digit_bits).map_err(|e| e.to_string())?;
    let cost_model = flag(flags, "cost-model", "").to_string();
    let cost = gpu_bucket_sort::algos::adaptive::CostModel::resolve(&cost_model)
        .map_err(|e| e.to_string())?;
    let ctx = || {
        ExecContext::new(kernel, 0)
            .with_digit_bits(digit_bits)
            .with_cost_model(cost)
    };

    if key_type != KeyType::U32 || payload || descending {
        if analytic {
            return Err("--analytic supports the classic u32 key-only path only".into());
        }
        return cmd_sort_typed(
            flags, n, dist, seed, engine, verify, key_type, payload, descending, kernel,
            digit_bits, cost_model,
        );
    }

    if engine == EngineKind::Sharded {
        return cmd_sort_sharded(flags, n, dist, seed, verify, analytic, ctx());
    }
    if analytic {
        return Err("--analytic is only supported with --engine sharded".into());
    }

    println!("generating {n} keys ({dist}) …");
    let input = dist.generate(n, seed);

    match engine {
        EngineKind::Native => {
            let e = NativeEngine::with_context(NativeParams::default(), ctx())
                .map_err(|e| e.to_string())?;
            let mut keys = input.clone();
            let report = e.sort(&mut keys);
            println!(
                "native sort: {:.2} ms  ({:.1} Mkeys/s, {} workers, {} buckets)",
                report.wall_ms,
                report.rate_mkeys_s(),
                e.workers(),
                report.buckets
            );
            println!(
                "  phases: local {:.2} | sampling {:.2} | indexing {:.2} | relocation {:.2} | buckets {:.2} ms",
                report.phases.local_sort_ms,
                report.phases.sampling_ms,
                report.phases.indexing_ms,
                report.phases.relocation_ms,
                report.phases.bucket_sort_ms
            );
            check(&input, &keys, verify)?;
        }
        EngineKind::Sim => {
            let device = GpuModel::parse(flag(flags, "device", "gtx285")).ok_or("unknown device")?;
            let algo = Algorithm::parse(flag(flags, "algo", "gbs")).ok_or("unknown algorithm")?;
            if flags.contains_key("kernel") && algo != Algorithm::BucketSort {
                return Err(format!(
                    "--kernel applies to {} only (the baselines execute their own kernels)",
                    Algorithm::BucketSort.canonical_name()
                ));
            }
            let mut keys = input.clone();
            let mut sim = GpuSim::new(device.spec());
            let t0 = Instant::now();
            // The bucket-sort arm honours the kernel selection (and its
            // arena); the ledger and estimate are identical for either
            // kernel. Baselines execute their own fixed kernels.
            let est_ms = algo
                .run_in(&mut keys, &mut sim, &ctx())
                .map_err(|e| e.to_string())?;
            println!(
                "{algo} on simulated {device}: estimated {est_ms:.2} ms on-device \
                 ({:.1} Mkeys/s), host execution {:.0} ms",
                n as f64 / est_ms / 1e3,
                t0.elapsed().as_secs_f64() * 1e3
            );
            println!(
                "  ledger: {} launches, {:.1} MB effective global traffic, peak device mem {:.1} MB",
                sim.ledger().kernel_count(),
                sim.ledger().total().effective_global_bytes() as f64 / 1e6,
                sim.peak_bytes() as f64 / 1e6
            );
            check(&input, &keys, verify)?;
        }
        EngineKind::Pjrt => {
            let dir = flag(flags, "artifacts-dir", "artifacts");
            let mut rt = PjrtRuntime::new(dir).map_err(|e| e.to_string())?;
            let t0 = Instant::now();
            let (sorted, cap) = rt.sort(&input).map_err(|e| e.to_string())?;
            println!(
                "pjrt sort via AOT artifact (capacity {cap}): {:.2} ms wall",
                t0.elapsed().as_secs_f64() * 1e3
            );
            check(&input, &sorted, verify)?;
        }
        EngineKind::Sharded => unreachable!("handled by cmd_sort_sharded"),
    }
    Ok(())
}

/// `gbs sort --engine sharded`: shard one input across a simulated
/// device pool. With `--analytic true`, price a paper-scale run (no
/// data generated — this is how the CLI demonstrates sorting beyond
/// any single device's memory ceiling).
fn cmd_sort_sharded(
    flags: &HashMap<String, String>,
    n: usize,
    dist: Distribution,
    seed: u64,
    verify: bool,
    analytic: bool,
    ctx: ExecContext,
) -> Result<(), String> {
    let default_devices = DevicePool::DEFAULT_DEVICES.map(|m| m.id()).join(",");
    let models = DevicePool::parse_list(flag(flags, "devices", &default_devices))
        .ok_or("unknown device in --devices list")?;
    let mut pool = DevicePool::new(&models).map_err(|e| e.to_string())?;
    let faults = gpu_bucket_sort::sim::FaultPlan::resolve(flag(flags, "fault-plan", ""))
        .map_err(|e| e.to_string())?
        .map(|plan| plan.injector());
    let ctx = ctx.with_faults(faults.clone());
    let sorter = ShardedSort::try_new(ShardedSortParams::default()).map_err(|e| e.to_string())?;
    println!(
        "device pool: {} devices, aggregate capacity {} keys",
        pool.len(),
        pool.max_sortable_keys()
    );

    let report = if analytic {
        println!("analytic mode: pricing {n} keys without generating data");
        sorter.sort_analytic(n, &mut pool).map_err(|e| e.to_string())?
    } else {
        println!("generating {n} keys ({dist}) …");
        let input = dist.generate(n, seed);
        let mut keys = input.clone();
        let t0 = Instant::now();
        let report = sorter
            .sort_in(&mut keys, &mut pool, &ctx)
            .map_err(|e| e.to_string())?;
        println!(
            "host execution {:.0} ms, largest destination shard {} keys",
            t0.elapsed().as_secs_f64() * 1e3,
            report.max_out_shard
        );
        check(&input, &keys, verify)?;
        report
    };

    for (d, sim) in pool.sims().iter().enumerate() {
        println!(
            "  device {d} ({}): shard {} keys, {} launches, est {:.2} ms, peak mem {:.1} MB",
            sim.spec().name,
            report.shard_sizes[d],
            sim.ledger().kernel_count(),
            sim.estimated_ms(),
            sim.peak_bytes() as f64 / 1e6
        );
    }
    println!(
        "sharded sort of {n} keys: estimated makespan {:.2} ms ({:.1} Mkeys/s across the pool)",
        report.makespan_ms(&pool),
        report.sort_rate_mkeys_s(&pool)
    );
    if let Some(inj) = &faults {
        for (point, count) in inj.injected() {
            println!("  fault injected: {point} ×{count} (recovered)");
        }
    }
    Ok(())
}

/// `gbs sort` with `--key-type`/`--payload`/`--descending`: the typed
/// job path, served by whichever engine `--engine` selects through the
/// same `SortEngine` surface the service uses.
#[allow(clippy::too_many_arguments)]
fn cmd_sort_typed(
    flags: &HashMap<String, String>,
    n: usize,
    dist: Distribution,
    seed: u64,
    engine: EngineKind,
    verify: bool,
    key_type: KeyType,
    payload: bool,
    descending: bool,
    kernel: KernelKind,
    digit_bits: u32,
    cost_model: String,
) -> Result<(), String> {
    // The typed path serves the deterministic sample sort; the
    // baselines (radix in particular) are u32-only, so an explicit
    // --algo other than bucket-sort is an error, not silently ignored.
    if let Some(a) = flags.get("algo") {
        let algo = Algorithm::parse(a).ok_or("unknown algorithm")?;
        if algo != Algorithm::BucketSort {
            return Err(format!(
                "--key-type/--payload/--descending serve {} only (the baselines are u32, key-only)",
                Algorithm::BucketSort.canonical_name()
            ));
        }
    }
    let mut cfg = ServiceConfig {
        engine,
        kernel,
        digit_bits,
        cost_model,
        ..ServiceConfig::default()
    };
    if let Some(d) = flags.get("device") {
        cfg.device = GpuModel::parse(d).ok_or("unknown device")?;
    }
    if let Some(ds) = flags.get("devices") {
        cfg.devices = DevicePool::parse_list(ds).ok_or("unknown device in --devices list")?;
    }
    if let Some(dir) = flags.get("artifacts-dir") {
        cfg.artifacts_dir = dir.clone();
    }
    if let Some(p) = flags.get("fault-plan") {
        cfg.fault_plan = p.clone();
    }
    cfg.validate().map_err(|e| e.to_string())?;
    let faults = gpu_bucket_sort::sim::FaultPlan::resolve(&cfg.fault_plan)
        .map_err(|e| e.to_string())?
        .map(|plan| plan.injector());

    println!(
        "generating {n} {key_type} keys ({dist}){} …",
        if payload { " with u64 payloads" } else { "" }
    );
    let keys = dist.generate_data(key_type, n, seed);
    let job = JobData {
        keys,
        payload: payload.then(|| (0..n as u64).collect()),
    };
    let reference = job.clone();

    let mut eng = build_engine_with_faults(&cfg, faults).map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    let result = eng
        .sort_batch(vec![job])
        .pop()
        .expect("engine answers every job");
    let mut out = result.map_err(|e| e.to_string())?;
    if descending {
        out.reverse();
    }
    println!(
        "typed sort ({key_type}, {}, {}): {:.2} ms host on the {} engine",
        if payload { "key–value" } else { "key-only" },
        if descending { "descending" } else { "ascending" },
        t0.elapsed().as_secs_f64() * 1e3,
        cfg.engine.id(),
    );
    if verify {
        verify_outcome(&reference, &out, descending)
            .map_err(|e| format!("verification FAILED: {e}"))?;
        println!(
            "  verified: sorted permutation{} ✓",
            if payload { " + payload pairing" } else { "" }
        );
    }
    Ok(())
}

/// `gbs sort --connect HOST:PORT`: submit the sort to a remote
/// `gbs serve --listen` server over the framed TCP protocol and verify
/// the response locally (the remote result is byte-identical to an
/// in-process run against the same service config).
#[allow(clippy::too_many_arguments)]
fn cmd_sort_remote(
    flags: &HashMap<String, String>,
    n: usize,
    dist: Distribution,
    seed: u64,
    verify: bool,
    key_type: KeyType,
    payload: bool,
    descending: bool,
    addr: &str,
) -> Result<(), String> {
    let connections: usize = flag(flags, "connections", "1")
        .parse()
        .map_err(|e| format!("bad --connections: {e}"))?;
    let client =
        NetClient::connect(addr, connections, NetConfig::default()).map_err(|e| e.to_string())?;
    if flag(flags, "drain", "false") == "true" {
        client.drain_server().map_err(|e| e.to_string())?;
        println!("drain acknowledged by {addr}");
        return Ok(());
    }
    println!(
        "generating {n} {key_type} keys ({dist}){} …",
        if payload { " with u64 payloads" } else { "" }
    );
    let keys = dist.generate_data(key_type, n, seed);
    let reference = JobData {
        keys: keys.clone(),
        payload: payload.then(|| (0..n as u64).collect()),
    };
    let mut builder = SortRequest::builder(keys).descending(descending);
    if payload {
        builder = builder.payload((0..n as u64).collect());
    }
    let request = builder.build().map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    let resp = client.sort(request).map_err(|e| e.to_string())?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "remote sort via {addr}: {wall_ms:.2} ms round trip ({:.1} Mkeys/s) — \
         engine {}, worker {}, batch {}, queue {:.2} ms, service {:.2} ms",
        n as f64 / wall_ms / 1e3,
        resp.engine.id(),
        resp.worker,
        resp.batch_size,
        resp.queue_ms,
        resp.service_ms,
    );
    if verify {
        let out = JobData {
            keys: resp.keys,
            payload: resp.payload,
        };
        verify_outcome(&reference, &out, descending)
            .map_err(|e| format!("verification FAILED: {e}"))?;
        println!(
            "  verified: sorted permutation{} ✓",
            if payload { " + payload pairing" } else { "" }
        );
    }
    Ok(())
}

/// `gbs sort --registry HOST:PORT`: resolve the cluster's node set
/// from the registry, route to the least-loaded node, and fail over to
/// a survivor if the chosen node dies mid-request.
#[allow(clippy::too_many_arguments)]
fn cmd_sort_cluster(
    flags: &HashMap<String, String>,
    n: usize,
    dist: Distribution,
    seed: u64,
    verify: bool,
    key_type: KeyType,
    payload: bool,
    descending: bool,
    reg_addr: &str,
) -> Result<(), String> {
    if flag(flags, "drain", "false") == "true" {
        registry::drain_registry(reg_addr).map_err(|e| e.to_string())?;
        println!("drain acknowledged by registry {reg_addr}");
        return Ok(());
    }
    let connections: usize = flag(flags, "connections", "1")
        .parse()
        .map_err(|e| format!("bad --connections: {e}"))?;
    let client = ClusterClient::connect(
        reg_addr,
        NetConfig::default(),
        ClusterOptions {
            connections_per_node: connections,
            ..ClusterOptions::default()
        },
    )
    .map_err(|e| e.to_string())?;
    let nodes = client.nodes();
    println!("cluster via registry {reg_addr}: {} node(s) {:?}", nodes.len(), nodes);
    println!(
        "generating {n} {key_type} keys ({dist}){} …",
        if payload { " with u64 payloads" } else { "" }
    );
    let keys = dist.generate_data(key_type, n, seed);
    let reference = JobData {
        keys: keys.clone(),
        payload: payload.then(|| (0..n as u64).collect()),
    };
    let mut builder = SortRequest::builder(keys).descending(descending);
    if payload {
        builder = builder.payload((0..n as u64).collect());
    }
    let request = builder.build().map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    let resp = client.sort(request).map_err(|e| e.to_string())?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "cluster sort: {wall_ms:.2} ms round trip ({:.1} Mkeys/s) — engine {}, \
         worker {}, batch {}, {} failover(s)",
        n as f64 / wall_ms / 1e3,
        resp.engine.id(),
        resp.worker,
        resp.batch_size,
        client.failovers(),
    );
    if verify {
        let out = JobData {
            keys: resp.keys,
            payload: resp.payload,
        };
        verify_outcome(&reference, &out, descending)
            .map_err(|e| format!("verification FAILED: {e}"))?;
        println!(
            "  verified: sorted permutation{} ✓",
            if payload { " + payload pairing" } else { "" }
        );
    }
    Ok(())
}

fn check(input: &[Key], output: &[Key], verify: bool) -> Result<(), String> {
    if verify {
        if is_sorted_permutation(input, output) {
            println!("  verified: sorted permutation ✓");
            Ok(())
        } else {
            Err("verification FAILED".into())
        }
    } else {
        Ok(())
    }
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<(), String> {
    let mut cfg = match flags.get("config") {
        Some(path) => ServiceConfig::from_file(path).map_err(|e| e.to_string())?,
        None => {
            let mut cfg = ServiceConfig::default();
            if let Some(e) = flags.get("engine") {
                cfg.engine = EngineKind::parse(e).ok_or("unknown engine")?;
            }
            cfg
        }
    };
    if let Some(w) = flags.get("workers") {
        cfg.workers = w.parse().map_err(|e| format!("bad --workers: {e}"))?;
    }
    if let Some(k) = flags.get("kernel") {
        cfg.kernel = KernelKind::parse(k).ok_or("unknown kernel")?;
    }
    if let Some(d) = flags.get("digit-bits") {
        cfg.digit_bits = d.parse().map_err(|e| format!("bad --digit-bits: {e}"))?;
    }
    if let Some(m) = flags.get("cost-model") {
        cfg.cost_model = m.clone();
    }
    if let Some(p) = flags.get("fault-plan") {
        cfg.fault_plan = p.clone();
    }
    if let Some(c) = flags.get("coalesce-max-keys") {
        cfg.batch.coalesce_max_keys = parse_size(c)?;
    }
    if let Some(d) = flags.get("drain-timeout-ms") {
        cfg.net.drain_timeout_ms = d
            .parse()
            .map_err(|e| format!("bad --drain-timeout-ms: {e}"))?;
    }
    cfg.validate().map_err(|e| e.to_string())?;
    if let Some(addr) = flags.get("listen") {
        return cmd_serve_listen(
            cfg,
            addr,
            flags.get("registry").map(String::as_str),
            flags.get("advertise").map(String::as_str),
        );
    }
    if flags.contains_key("registry") {
        return Err("--registry requires --listen (a clusterable node serves over TCP)".into());
    }
    let requests: usize = flag(flags, "requests", "64").parse().map_err(|e| format!("{e}"))?;
    let concurrency: usize = flag(flags, "concurrency", "8").parse().map_err(|e| format!("{e}"))?;
    let n = parse_size(flag(flags, "n", "1M"))?;
    let dist = Distribution::parse(flag(flags, "dist", "uniform")).ok_or("unknown distribution")?;
    let key_type = KeyType::parse(flag(flags, "key-type", "u32")).ok_or("unknown key type")?;
    let payload = flag(flags, "payload", "false") == "true";
    let descending = flag(flags, "descending", "false") == "true";

    println!(
        "service: engine={:?}, {} worker(s), {requests} requests × {n} {key_type} keys ({dist}{}{}), {concurrency} client threads",
        cfg.engine,
        cfg.workers,
        if payload { ", key–value" } else { "" },
        if descending { ", descending" } else { "" },
    );
    let client = SortService::start(cfg).map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..concurrency {
            let client = client.clone();
            scope.spawn(move || {
                for r in 0..requests / concurrency.max(1) {
                    let seed = (w * 1000 + r) as u64;
                    let keys = dist.generate_data(key_type, n, seed);
                    let mut builder = SortRequest::builder(keys).descending(descending);
                    if payload {
                        builder = builder.payload((0..n as u64).collect());
                    }
                    let request = builder.build().expect("request is structurally valid");
                    match client.sort(request) {
                        Ok(out) => {
                            assert!(out.keys.is_sorted(descending));
                        }
                        Err(e) => eprintln!("request failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let snap = client.shutdown();
    let sorted = snap.counters.get("keys_sorted").copied().unwrap_or(0);
    println!(
        "done in {wall:.2}s — {:.1} Mkeys/s aggregate\n{}",
        sorted as f64 / wall / 1e6,
        snap.summary()
    );
    Ok(())
}

/// `gbs serve --listen ADDR`: serve sorts over TCP until some client
/// sends a `Drain` frame, then drain gracefully (in-flight sorts
/// complete and flush before the listener goes down). With
/// `--registry`, the node self-registers on start and — in that order —
/// deregisters, *then* drains on shutdown, so the registry stops
/// routing new work here before the node starts shedding.
fn cmd_serve_listen(
    cfg: ServiceConfig,
    addr: &str,
    registry_addr: Option<&str>,
    advertise: Option<&str>,
) -> Result<(), String> {
    let net = cfg.net;
    let engine = cfg.engine;
    let workers = cfg.workers;
    let client = SortService::start(cfg).map_err(|e| e.to_string())?;
    let server = NetServer::bind(addr, client, net).map_err(|e| e.to_string())?;
    // The machine-scrapable address line comes first (port 0 resolves
    // to the ephemeral port actually bound).
    println!("GBS_NET_ADDR {}", server.local_addr());
    let registration = match registry_addr {
        Some(reg_addr) => {
            let advertised = advertise
                .map(str::to_string)
                .unwrap_or_else(|| server.local_addr().to_string());
            let reg = NodeRegistration::start(
                reg_addr,
                &advertised,
                server.load_probe(),
                Duration::from_millis(net.drain_timeout_ms),
            )
            .map_err(|e| e.to_string())?;
            println!("registered with {reg_addr} as {advertised}");
            Some(reg)
        }
        None => None,
    };
    println!(
        "serving sorts over TCP: engine={engine:?}, {workers} worker(s), \
         {} credits/connection — stop with `gbs sort --connect {} --drain true`",
        net.credits,
        server.local_addr()
    );
    {
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    }
    server.wait_for_drain_request(None);
    // Deregister-then-drain: the registry must stop routing to this
    // node before in-flight work starts shedding.
    if let Some(reg) = registration {
        let acked = reg.deregister();
        println!(
            "deregistered from registry ({}) — completing in-flight sorts …",
            if acked { "acked" } else { "no ack; lease will expire" }
        );
    } else {
        println!("drain requested — completing in-flight sorts …");
    }
    let snap = server.shutdown();
    println!("{}", snap.summary());
    Ok(())
}

/// `gbs registry`: run the cluster membership registry until some
/// client asks it to drain (`gbs sort --registry ADDR --drain true`).
fn cmd_registry(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = flag(flags, "listen", "127.0.0.1:0");
    let mut cfg = RegistryConfig::default();
    if let Some(v) = flags.get("heartbeat-ms") {
        cfg.heartbeat_ms = v.parse().map_err(|e| format!("bad --heartbeat-ms: {e}"))?;
    }
    if let Some(v) = flags.get("suspect-misses") {
        cfg.suspect_misses = v
            .parse()
            .map_err(|e| format!("bad --suspect-misses: {e}"))?;
    }
    if let Some(v) = flags.get("evict-misses") {
        cfg.evict_misses = v.parse().map_err(|e| format!("bad --evict-misses: {e}"))?;
    }
    let reg = Registry::bind(addr, cfg).map_err(|e| e.to_string())?;
    // Machine-scrapable address line first (port 0 resolves here).
    println!("GBS_REGISTRY_ADDR {}", reg.local_addr());
    println!(
        "registry: heartbeat {} ms, suspect after {} missed, evict after {} missed \
         — stop with `gbs sort --registry {} --drain true`",
        cfg.heartbeat_ms,
        cfg.suspect_misses,
        cfg.evict_misses,
        reg.local_addr()
    );
    {
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    }
    reg.wait_for_drain_request(None);
    println!("drain requested — closing registry …");
    let snap = reg.shutdown();
    println!("{}", snap.summary());
    Ok(())
}

fn cmd_experiment(flags: &HashMap<String, String>) -> Result<(), String> {
    let which = flags
        .get("_arg")
        .map(String::as_str)
        .ok_or("which experiment? (table1|fig3|fig4|fig5|fig6|fig7|robustness|rates|sharded|all)")?;
    let out_dir = std::path::PathBuf::from(flag(flags, "out", "results"));
    let fast = flag(flags, "fast", "false") == "true";

    let max_n = if fast { 32 << 20 } else { 512 << 20 };
    let ladder = exp::paper_n_ladder(max_n);
    let ladder_256 = exp::paper_n_ladder(max_n.min(256 << 20));
    let fig3_ns: Vec<usize> = if fast {
        vec![32 << 20]
    } else {
        exp::FIG3_NS.to_vec()
    };
    let robustness_n = if fast { 1 << 17 } else { 1 << 20 };

    let mut tables = Vec::new();
    match which {
        "table1" => tables.push(exp::table1()),
        "fig3" => tables.push(exp::fig3_sample_size(&fig3_ns, &exp::FIG3_S_VALUES)),
        "fig4" => tables.push(exp::fig4_devices(&ladder)),
        "fig5" => tables.push(exp::fig5_step_breakdown(&ladder_256)),
        "fig6" => tables.push(exp::fig6_gtx285(&ladder_256)),
        "fig7" => tables.push(exp::fig7_tesla(&ladder)),
        "rates" => tables.push(exp::sort_rate_series(&ladder, GpuModel::TeslaC1060)),
        "sharded" => tables.push(exp::sharded_scaling(
            &ladder,
            &[1, 2, 4, 8],
            GpuModel::Gtx285_2G,
        )),
        "robustness" => {
            let (t, g, r) = exp::robustness(robustness_n, 7);
            println!("spread (max/min − 1): deterministic {g:.4}, randomized {r:.4}");
            tables.push(t);
        }
        "all" => {
            tables.push(exp::table1());
            tables.push(exp::fig3_sample_size(&fig3_ns, &exp::FIG3_S_VALUES));
            tables.push(exp::fig4_devices(&ladder));
            tables.push(exp::fig5_step_breakdown(&ladder_256));
            tables.push(exp::fig6_gtx285(&ladder_256));
            tables.push(exp::fig7_tesla(&ladder));
            tables.push(exp::sort_rate_series(&ladder, GpuModel::TeslaC1060));
            tables.push(exp::sharded_scaling(
                &ladder,
                &[1, 2, 4, 8],
                GpuModel::Gtx285_2G,
            ));
            let (t, g, r) = exp::robustness(robustness_n, 7);
            println!("robustness spread: deterministic {g:.4}, randomized {r:.4}");
            tables.push(t);
        }
        other => return Err(format!("unknown experiment {other:?}")),
    }
    for t in &tables {
        println!("{}", t.to_markdown());
        let path = t.write_csv(&out_dir).map_err(|e| e.to_string())?;
        println!("→ {}\n", path.display());
    }
    Ok(())
}

fn cmd_config(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = match flags.get("file") {
        Some(path) => ServiceConfig::from_file(path).map_err(|e| e.to_string())?,
        None => ServiceConfig::default(),
    };
    println!("{}", cfg.to_json());
    Ok(())
}

fn cmd_artifacts(flags: &HashMap<String, String>) -> Result<(), String> {
    let dir = flag(flags, "dir", "artifacts");
    let mut rt = PjrtRuntime::new(dir).map_err(|e| e.to_string())?;
    println!(
        "manifest: {} entries, platform {}",
        rt.manifest().entries.len(),
        rt.platform()
    );
    let compiled = rt.warm_up().map_err(|e| e.to_string())?;
    println!("compiled {compiled} full-sort executables");
    for n in [100usize, 4096] {
        let keys = Distribution::Uniform.generate(n, 42);
        let t0 = Instant::now();
        let (sorted, cap) = rt.sort(&keys).map_err(|e| e.to_string())?;
        if !is_sorted_permutation(&keys, &sorted) {
            return Err(format!("artifact produced wrong output at n={n}"));
        }
        println!(
            "  n={n}: ok via capacity {cap} in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    println!("artifacts OK");
    Ok(())
}

//! Hardware specifications — the paper's Table 1, plus the Tesla-
//! architecture constants of §2 (SM count, shared memory per SM, warp and
//! block sizes) needed by the cost model.
//!
//! | | Tesla C1060 | GTX 285 (2 GB) | GTX 285 (1 GB) | GTX 260 |
//! |---|---|---|---|---|
//! | cores | 240 | 240 | 240 | 216 |
//! | core clock | 602 MHz | 648 MHz | 648 MHz | 576 MHz |
//! | global memory | 4 GB | 2 GB | 1 GB | 896 MB |
//! | memory clock | 1600 MHz | 2322 MHz | 2484 MHz | 1998 MHz |
//! | bandwidth | 102 GB/s | 149 GB/s | 159 GB/s | 112 GB/s |


/// Cores per streaming multiprocessor on the Tesla architecture (§2).
pub const CORES_PER_SM: u32 = 8;

/// Shared memory per SM in bytes (§2: "a small size (16 KB) low latency
/// local shared memory").
pub const SHARED_MEM_BYTES: usize = 16 * 1024;

/// Threads per warp (§2).
pub const WARP_SIZE: u32 = 32;

/// Maximum threads per block (§2: "blocks of up to 512 threads").
pub const MAX_BLOCK_THREADS: u32 = 512;

/// Global-memory transaction granularity in bytes. Tesla-class GPUs
/// service global memory in 32/64/128-byte segments; scattered accesses
/// degrade to one segment per request, which is how the cost model
/// penalizes non-coalesced access.
pub const MEM_TRANSACTION_BYTES: usize = 64;

/// Fraction of global memory usable by an application.
///
/// The paper's reported ceilings pin this to 1.0 and reveal the
/// allocation discipline: 256M keys on the 2 GiB GTX 285 and 512M on
/// the 4 GiB Tesla each equal **exactly** two n-key buffers of 4-byte
/// keys (2·256M·4 B = 2 GiB; 2·512M·4 B = 4 GiB). The implementation
/// therefore cannot hold *any* standalone auxiliary arrays at peak —
/// the sample/boundary/location matrices and the Step-9 scratch must
/// live inside whichever of the two big buffers is dead at that phase.
/// [`crate::algos::bucket_sort`] models exactly that (and checks the
/// aux fits). The same model yields the GTX 260's 64M ceiling
/// (128M × 8 B = 1 GiB > 896 MiB).
pub const USABLE_MEMORY_FRACTION: f64 = 1.0;

/// A GPU hardware description (one column of the paper's Table 1 plus the
/// §2 architecture constants).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name, e.g. "GTX 285 (2 GB)".
    pub name: String,
    /// Total processor cores (`sm_count * CORES_PER_SM`).
    pub cores: u32,
    /// Streaming multiprocessor count.
    pub sm_count: u32,
    /// Core (graphics) clock in MHz — the Table 1 value.
    pub core_clock_mhz: u32,
    /// Shader (processor) clock in MHz: the rate the CUDA cores actually
    /// execute at on Tesla-architecture parts (~2.3× the graphics
    /// clock); this is what compute throughput derives from.
    pub shader_clock_mhz: u32,
    /// Global DRAM size in bytes.
    pub global_memory_bytes: usize,
    /// Memory clock in MHz (Table 1; informational — bandwidth below is
    /// what the cost model uses).
    pub memory_clock_mhz: u32,
    /// Peak memory bandwidth in GB/s (10^9 bytes per second).
    pub memory_bandwidth_gbs: f64,
    /// Shared memory per SM in bytes.
    pub shared_mem_bytes: usize,
}

impl GpuSpec {
    /// Global memory available to the sort after driver/context reserve.
    pub fn usable_global_memory_bytes(&self) -> usize {
        (self.global_memory_bytes as f64 * USABLE_MEMORY_FRACTION) as usize
    }

    /// Peak bandwidth in bytes per millisecond.
    pub fn bandwidth_bytes_per_ms(&self) -> f64 {
        self.memory_bandwidth_gbs * 1e9 / 1e3
    }

    /// Aggregate scalar-op throughput in operations per millisecond:
    /// `cores × shader_clock`. (A deliberately simple peak; the cost
    /// model's per-class efficiency factors absorb SIMT divergence,
    /// dual-issue, etc.)
    pub fn compute_ops_per_ms(&self) -> f64 {
        self.cores as f64 * self.shader_clock_mhz as f64 * 1e6 / 1e3
    }

    /// Shared-memory aggregate throughput in accesses per millisecond.
    /// §2: shared memory is "at least an order of magnitude faster" than
    /// global memory; we model one access per core per clock.
    pub fn shared_ops_per_ms(&self) -> f64 {
        self.compute_ops_per_ms()
    }

    /// Tile capacity in keys: how many 4-byte keys fit in one SM's shared
    /// memory, halved for double-buffering/ping-pong space — this gives
    /// the paper's n/m = 2K items per sublist.
    pub fn tile_keys(&self) -> usize {
        self.shared_mem_bytes / crate::KEY_BYTES / 2
    }

    /// Maximum number of 4-byte keys GPU Bucket Sort can sort on this
    /// device: the algorithm keeps the input array plus one relocation
    /// buffer resident (2 × 4 B per key) plus the sample arrays.
    pub fn max_sortable_keys(&self) -> usize {
        self.usable_global_memory_bytes() / (2 * crate::KEY_BYTES)
    }
}

/// The four devices of the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuModel {
    /// Tesla C1060: 240 cores, 4 GB, 102 GB/s.
    TeslaC1060,
    /// GTX 285 with 2 GB (the paper's main benchmark device).
    Gtx285_2G,
    /// GTX 285 with 1 GB (the device of Leischner et al. [9]).
    Gtx285_1G,
    /// GTX 260: 216 cores, 896 MB, 112 GB/s.
    Gtx260,
}

impl GpuModel {
    /// All Table 1 devices, in the paper's column order.
    pub const ALL: [GpuModel; 4] = [
        GpuModel::TeslaC1060,
        GpuModel::Gtx285_2G,
        GpuModel::Gtx285_1G,
        GpuModel::Gtx260,
    ];

    /// The Table 1 column for this model.
    pub fn spec(self) -> GpuSpec {
        match self {
            GpuModel::TeslaC1060 => GpuSpec {
                name: "Tesla C1060".into(),
                cores: 240,
                sm_count: 30,
                core_clock_mhz: 602,
                shader_clock_mhz: 1296,
                global_memory_bytes: 4 * 1024 * 1024 * 1024,
                memory_clock_mhz: 1600,
                memory_bandwidth_gbs: 102.0,
                shared_mem_bytes: SHARED_MEM_BYTES,
            },
            GpuModel::Gtx285_2G => GpuSpec {
                name: "GTX 285 (2 GB)".into(),
                cores: 240,
                sm_count: 30,
                core_clock_mhz: 648,
                shader_clock_mhz: 1476,
                global_memory_bytes: 2 * 1024 * 1024 * 1024,
                memory_clock_mhz: 2322,
                memory_bandwidth_gbs: 149.0,
                shared_mem_bytes: SHARED_MEM_BYTES,
            },
            GpuModel::Gtx285_1G => GpuSpec {
                name: "GTX 285 (1 GB)".into(),
                cores: 240,
                sm_count: 30,
                core_clock_mhz: 648,
                shader_clock_mhz: 1476,
                global_memory_bytes: 1024 * 1024 * 1024,
                memory_clock_mhz: 2484,
                memory_bandwidth_gbs: 159.0,
                shared_mem_bytes: SHARED_MEM_BYTES,
            },
            GpuModel::Gtx260 => GpuSpec {
                name: "GTX 260".into(),
                cores: 216,
                sm_count: 27,
                core_clock_mhz: 576,
                shader_clock_mhz: 1242,
                global_memory_bytes: 896 * 1024 * 1024,
                memory_clock_mhz: 1998,
                memory_bandwidth_gbs: 112.0,
                shared_mem_bytes: SHARED_MEM_BYTES,
            },
        }
    }

    /// Stable user-facing identifier (CLI, config files, CSV) — the
    /// inverse of [`GpuModel::parse`].
    pub fn id(&self) -> &'static str {
        match self {
            GpuModel::TeslaC1060 => "tesla",
            GpuModel::Gtx285_2G => "gtx285",
            GpuModel::Gtx285_1G => "gtx285-1g",
            GpuModel::Gtx260 => "gtx260",
        }
    }

    /// Parse a user-facing device name (CLI, config files).
    pub fn parse(s: &str) -> Option<GpuModel> {
        match s.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "tesla" | "teslac1060" | "c1060" => Some(GpuModel::TeslaC1060),
            "gtx285" | "gtx2852g" | "gtx2852gb" => Some(GpuModel::Gtx285_2G),
            "gtx2851g" | "gtx2851gb" => Some(GpuModel::Gtx285_1G),
            "gtx260" => Some(GpuModel::Gtx260),
            _ => None,
        }
    }
}

impl std::fmt::Display for GpuModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.spec().name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 row "Number Of Cores": SMs × 8 cores must reproduce it.
    #[test]
    fn table1_core_counts() {
        for m in GpuModel::ALL {
            let s = m.spec();
            assert_eq!(s.cores, s.sm_count * CORES_PER_SM, "{}", s.name);
        }
        assert_eq!(GpuModel::TeslaC1060.spec().cores, 240);
        assert_eq!(GpuModel::Gtx285_2G.spec().cores, 240);
        assert_eq!(GpuModel::Gtx285_1G.spec().cores, 240);
        assert_eq!(GpuModel::Gtx260.spec().cores, 216);
    }

    /// Table 1 rows: clocks, memory sizes, bandwidths.
    #[test]
    fn table1_values() {
        let t = GpuModel::TeslaC1060.spec();
        assert_eq!(t.core_clock_mhz, 602);
        assert_eq!(t.memory_clock_mhz, 1600);
        assert_eq!(t.global_memory_bytes, 4 << 30);
        assert!((t.memory_bandwidth_gbs - 102.0).abs() < 1e-9);

        let g2 = GpuModel::Gtx285_2G.spec();
        assert_eq!(g2.core_clock_mhz, 648);
        assert_eq!(g2.memory_clock_mhz, 2322);
        assert!((g2.memory_bandwidth_gbs - 149.0).abs() < 1e-9);

        let g1 = GpuModel::Gtx285_1G.spec();
        assert_eq!(g1.memory_clock_mhz, 2484);
        assert!((g1.memory_bandwidth_gbs - 159.0).abs() < 1e-9);

        let g260 = GpuModel::Gtx260.spec();
        assert_eq!(g260.core_clock_mhz, 576);
        assert_eq!(g260.global_memory_bytes, 896 << 20);
        assert!((g260.memory_bandwidth_gbs - 112.0).abs() < 1e-9);
    }

    /// §2: "GTX 285 and Tesla GPUs have 30 SMs ... GTX 260 has 27 SMs".
    #[test]
    fn section2_sm_counts() {
        assert_eq!(GpuModel::TeslaC1060.spec().sm_count, 30);
        assert_eq!(GpuModel::Gtx285_2G.spec().sm_count, 30);
        assert_eq!(GpuModel::Gtx260.spec().sm_count, 27);
    }

    /// The paper's n/m = 2K-item sublists follow from 16 KB shared memory.
    #[test]
    fn tile_capacity_is_2k_items() {
        assert_eq!(GpuModel::Gtx285_2G.spec().tile_keys(), 2048);
    }

    /// Paper §5 memory ceilings: 64M on GTX 260, 256M on GTX 285 (2 GB),
    /// 512M on Tesla C1060.
    #[test]
    fn paper_memory_ceilings() {
        let ceil = |m: GpuModel| m.spec().max_sortable_keys();
        assert!(ceil(GpuModel::Gtx260) >= 64 << 20, "{}", ceil(GpuModel::Gtx260));
        assert!(ceil(GpuModel::Gtx260) < 128 << 20);
        assert!(ceil(GpuModel::Gtx285_2G) >= 256 << 20);
        assert!(ceil(GpuModel::Gtx285_2G) < 512 << 20);
        assert!(ceil(GpuModel::TeslaC1060) >= 512 << 20);
        assert!(ceil(GpuModel::TeslaC1060) < 1024 << 20);
    }

    #[test]
    fn parse_names() {
        assert_eq!(GpuModel::parse("Tesla"), Some(GpuModel::TeslaC1060));
        assert_eq!(GpuModel::parse("gtx 285"), Some(GpuModel::Gtx285_2G));
        assert_eq!(GpuModel::parse("GTX-285-1G"), Some(GpuModel::Gtx285_1G));
        assert_eq!(GpuModel::parse("gtx260"), Some(GpuModel::Gtx260));
        assert_eq!(GpuModel::parse("fermi"), None);
    }

    #[test]
    fn id_roundtrips_through_parse() {
        for m in GpuModel::ALL {
            assert_eq!(GpuModel::parse(m.id()), Some(m), "{m}");
        }
    }

    #[test]
    fn derived_rates() {
        let s = GpuModel::Gtx285_2G.spec();
        // 149 GB/s = 149e6 bytes per ms.
        assert!((s.bandwidth_bytes_per_ms() - 149e6).abs() < 1.0);
        // 240 cores * 1476 MHz shader clock = 354.24e6 ops/ms.
        assert!((s.compute_ops_per_ms() - 354.24e6).abs() < 1e3);
        assert_eq!(s.usable_global_memory_bytes(), s.global_memory_bytes);
    }
}

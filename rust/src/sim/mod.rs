//! GPU cost-model simulator — the hardware substitution for the paper's
//! 2009-era nVIDIA testbed (Tesla C1060, GTX 285, GTX 260).
//!
//! The paper measures wall-clock time on real GPUs. We have none, so every
//! algorithm in [`crate::algos`] runs against a [`GpuSim`]: the *data work
//! is done for real on the host* (so correctness is genuinely tested),
//! while the simulator keeps an exact [`Ledger`] of the traffic the same
//! algorithm would generate on the GPU — coalesced global-memory bytes,
//! scattered transactions, shared-memory operations, compute operations,
//! and kernel launches — per kernel launch. [`cost`] converts a ledger
//! into estimated milliseconds for a given [`GpuSpec`] using a
//! bandwidth/compute roofline per launch.
//!
//! The paper itself establishes that its method is **memory-bandwidth
//! bound** (§5: GPU ordering follows memory bandwidth, not core count), so
//! a traffic-exact bandwidth model reproduces the *shape* of every figure:
//! linear growth in n, the s=64 minimum of Figure 3, the per-step
//! breakdown of Figure 5, the device ordering of Figure 4, and the
//! capacity ceilings of Figures 6 & 7.
//!
//! Two accounting modes keep paper-scale experiments feasible:
//! * **Execute** — real data moves, exact counts (tests, service path).
//! * **Analytic** — closed-form counts without data (n up to 512M as in
//!   Figure 7). Property tests assert both modes produce identical
//!   ledgers on small inputs.

pub mod cost;
pub mod fault;
pub mod ledger;
pub mod pool;
pub mod spec;

pub use cost::{CostModel, CostParams};
pub use fault::{DeviceFault, FaultInjector, FaultPlan, FaultPoint};
pub use ledger::{KernelClass, KernelStats, Ledger, StepLedger};
pub use pool::{DeviceLease, DevicePool, DeviceRegistry};
pub use spec::{GpuModel, GpuSpec};

use crate::error::{Error, Result};

/// A simulated GPU: a spec, an allocation tracker that enforces the
/// device's global-memory capacity, and a traffic ledger.
///
/// Algorithms request allocations through [`GpuSim::alloc`] before touching
/// host buffers that stand in for device memory; this is what reproduces
/// the paper's memory ceilings (GTX 260 → 64M items, GTX 285 2GB → 256M,
/// Tesla C1060 → 512M; Figures 6 & 7).
#[derive(Debug, Clone)]
pub struct GpuSim {
    spec: GpuSpec,
    ledger: Ledger,
    allocated_bytes: usize,
    peak_bytes: usize,
}

impl GpuSim {
    /// Create a fresh simulator for the given hardware spec.
    pub fn new(spec: GpuSpec) -> Self {
        GpuSim {
            spec,
            ledger: Ledger::default(),
            allocated_bytes: 0,
            peak_bytes: 0,
        }
    }

    /// The hardware spec this simulator models.
    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }

    /// The accumulated traffic ledger.
    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    /// Mutable ledger access for the algorithm implementations.
    pub fn ledger_mut(&mut self) -> &mut Ledger {
        &mut self.ledger
    }

    /// Reset traffic and allocation state, keeping the spec.
    pub fn reset(&mut self) {
        self.ledger = Ledger::default();
        self.allocated_bytes = 0;
        self.peak_bytes = 0;
    }

    /// Claim `bytes` of simulated device global memory.
    ///
    /// Fails with [`Error::DeviceOom`] when the device's usable capacity
    /// (total minus the reserved fraction the driver/framebuffer holds
    /// back) would be exceeded — this models the paper's per-device
    /// maximum-sortable-n limits.
    pub fn alloc(&mut self, bytes: usize) -> Result<Allocation> {
        let usable = self.spec.usable_global_memory_bytes();
        let available = usable.saturating_sub(self.allocated_bytes);
        if bytes > available {
            return Err(Error::DeviceOom {
                requested: bytes,
                available,
                device: self.spec.name.clone(),
            });
        }
        self.allocated_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.allocated_bytes);
        Ok(Allocation { bytes })
    }

    /// Release an allocation previously returned by [`GpuSim::alloc`].
    pub fn free(&mut self, alloc: Allocation) {
        self.allocated_bytes = self.allocated_bytes.saturating_sub(alloc.bytes);
    }

    /// Currently allocated simulated device bytes.
    pub fn allocated_bytes(&self) -> usize {
        self.allocated_bytes
    }

    /// High-water mark of simulated device memory.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Estimated total milliseconds for everything recorded so far, using
    /// the default cost parameters.
    pub fn estimated_ms(&self) -> f64 {
        CostModel::default_params(&self.spec).ledger_ms(&self.ledger)
    }
}

/// Token for a simulated device-memory allocation; return it to
/// [`GpuSim::free`]. Deliberately not `Copy` so double-frees are caught at
/// compile time.
#[derive(Debug)]
#[must_use = "allocations must be freed back to the GpuSim"]
pub struct Allocation {
    bytes: usize,
}

impl Allocation {
    /// Size of this allocation in bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut sim = GpuSim::new(GpuModel::Gtx260.spec());
        let a = sim.alloc(1024).unwrap();
        assert_eq!(sim.allocated_bytes(), 1024);
        let b = sim.alloc(2048).unwrap();
        assert_eq!(sim.allocated_bytes(), 3072);
        assert_eq!(sim.peak_bytes(), 3072);
        sim.free(a);
        assert_eq!(sim.allocated_bytes(), 2048);
        sim.free(b);
        assert_eq!(sim.allocated_bytes(), 0);
        assert_eq!(sim.peak_bytes(), 3072);
    }

    #[test]
    fn oom_on_capacity() {
        let mut sim = GpuSim::new(GpuModel::Gtx260.spec());
        let usable = sim.spec().usable_global_memory_bytes();
        let err = sim.alloc(usable + 1).unwrap_err();
        assert!(err.is_oom());
        // Exactly-usable succeeds.
        let a = sim.alloc(usable).unwrap();
        assert!(sim.alloc(1).unwrap_err().is_oom());
        sim.free(a);
        assert!(sim.alloc(1).is_ok());
    }

    #[test]
    fn reset_clears_state() {
        let mut sim = GpuSim::new(GpuModel::TeslaC1060.spec());
        let _a = sim.alloc(100).unwrap();
        sim.ledger_mut().begin_kernel(KernelClass::LocalSort, 1, 1);
        sim.reset();
        assert_eq!(sim.allocated_bytes(), 0);
        assert_eq!(sim.ledger().kernel_count(), 0);
    }
}

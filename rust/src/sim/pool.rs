//! A pool of simulated GPUs — the substrate of the sharded sort engine.
//!
//! The paper's system is a single device, and its Figures 6 & 7 show
//! exactly where that ends: the sort dies at the device's global-memory
//! ceiling (64M keys on the GTX 260, 256M on the GTX 285 2 GB, 512M on
//! the Tesla C1060). A [`DevicePool`] groups several (possibly
//! heterogeneous) [`GpuSim`]s so [`crate::algos::sharded`] can partition
//! one input across them, which removes the single-device ceiling: the
//! pool's capacity is the *sum* of its members'.
//!
//! Shards are **capacity-weighted**: each device receives a slice of the
//! input proportional to its [`GpuSpec::max_sortable_keys`], so a mixed
//! Tesla/GTX pool fills every card to the same fraction of its memory
//! and no card becomes the OOM bottleneck before the pool as a whole is
//! full. The partition is deterministic in `(n, pool)` — a requirement
//! for the sharded sort's Execute/Analytic ledger equality.

use super::spec::{GpuModel, GpuSpec};
use super::GpuSim;
use crate::error::{Error, Result};
use std::sync::{Arc, Mutex};

/// A fixed set of simulated devices, each with its own traffic ledger
/// and memory-capacity tracking.
///
/// Devices can be marked **unhealthy** (a [`crate::Error::DeviceLost`]
/// mid-step): an unhealthy device keeps its slot — so pool indices stay
/// stable and reports stay pool-aligned — but receives a zero share in
/// [`DevicePool::shares`] and contributes nothing to the capacity sum.
/// The last healthy device can never be marked, so a pool always has
/// somewhere to run.
#[derive(Debug, Clone)]
pub struct DevicePool {
    sims: Vec<GpuSim>,
    /// `true` at index `d` once device `d` was lost. Survives
    /// [`DevicePool::reset`] — a dead device stays dead across jobs.
    unhealthy: Vec<bool>,
}

impl DevicePool {
    /// The default heterogeneous pool: one of each Table 1 device,
    /// coordinator (device 0) first. Total capacity 1008M keys —
    /// roughly twice the best single card.
    pub const DEFAULT_DEVICES: [GpuModel; 4] = [
        GpuModel::Gtx285_2G,
        GpuModel::TeslaC1060,
        GpuModel::Gtx285_1G,
        GpuModel::Gtx260,
    ];

    /// Build a pool from Table 1 models. Errors on an empty list.
    pub fn new(models: &[GpuModel]) -> Result<Self> {
        Self::from_specs(models.iter().map(|m| m.spec()).collect())
    }

    /// Build a pool from explicit hardware specs (tests use tiny
    /// synthetic devices). Errors on an empty list.
    pub fn from_specs(specs: Vec<GpuSpec>) -> Result<Self> {
        if specs.is_empty() {
            return Err(Error::InvalidParams(
                "a device pool needs at least one device".into(),
            ));
        }
        let sims: Vec<GpuSim> = specs.into_iter().map(GpuSim::new).collect();
        let unhealthy = vec![false; sims.len()];
        Ok(DevicePool { sims, unhealthy })
    }

    /// Parse a comma-separated device list, e.g. `"gtx285,tesla,gtx260"`.
    /// Returns `None` if any name is unknown or the list is empty.
    pub fn parse_list(s: &str) -> Option<Vec<GpuModel>> {
        let models: Option<Vec<GpuModel>> = s
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(GpuModel::parse)
            .collect();
        models.filter(|m| !m.is_empty())
    }

    /// Number of devices in the pool.
    pub fn len(&self) -> usize {
        self.sims.len()
    }

    /// True when the pool holds no devices (never, post-construction).
    pub fn is_empty(&self) -> bool {
        self.sims.is_empty()
    }

    /// The member simulators (ledgers, peak memory).
    pub fn sims(&self) -> &[GpuSim] {
        &self.sims
    }

    /// Mutable access to one device's simulator.
    pub fn sim_mut(&mut self, device: usize) -> &mut GpuSim {
        &mut self.sims[device]
    }

    /// One device's hardware spec.
    pub fn spec(&self, device: usize) -> &GpuSpec {
        self.sims[device].spec()
    }

    /// Pool capacity in keys: the sum of every *healthy* member's
    /// single-device ceiling. This is the number the sharded engine
    /// advertises to the coordinator's admission control; it shrinks
    /// when a device is lost.
    pub fn max_sortable_keys(&self) -> usize {
        self.sims
            .iter()
            .zip(&self.unhealthy)
            .filter(|(_, &dead)| !dead)
            .map(|(s, _)| s.spec().max_sortable_keys())
            .sum()
    }

    /// Mark device `d` unhealthy after a [`crate::Error::DeviceLost`]:
    /// it keeps its slot but gets zero-weighted in [`DevicePool::shares`]
    /// and excluded from the capacity sum. Refuses to mark the last
    /// healthy device — a pool must always have somewhere to run.
    pub fn mark_unhealthy(&mut self, device: usize) -> Result<()> {
        if self.healthy_count() <= 1 && !self.unhealthy[device] {
            return Err(Error::Coordinator(format!(
                "cannot mark device {device} ({}) unhealthy: it is the pool's \
                 last healthy device",
                self.sims[device].spec().name
            )));
        }
        self.unhealthy[device] = true;
        Ok(())
    }

    /// True while device `d` has not been lost.
    pub fn is_healthy(&self, device: usize) -> bool {
        !self.unhealthy[device]
    }

    /// Number of devices still healthy.
    pub fn healthy_count(&self) -> usize {
        self.unhealthy.iter().filter(|&&dead| !dead).count()
    }

    /// Pool indices of the healthy devices, ascending.
    pub fn healthy_indices(&self) -> Vec<usize> {
        (0..self.sims.len())
            .filter(|&d| !self.unhealthy[d])
            .collect()
    }

    /// Clear all unhealthy marks (benchmarks re-baselining between
    /// scenarios; a real recovery would re-probe the device first).
    pub fn restore_health(&mut self) {
        self.unhealthy.iter_mut().for_each(|d| *d = false);
    }

    /// Capacity-weighted partition of `n` keys: `shares[d]` is
    /// proportional to device `d`'s [`GpuSpec::max_sortable_keys`],
    /// rounded by the largest-remainder method (remainders go to the
    /// highest-capacity devices, index order breaking ties), and the
    /// shares always sum to exactly `n`. Deterministic in `(n, pool)`.
    pub fn shares(&self, n: usize) -> Vec<usize> {
        let weights: Vec<u128> = self
            .sims
            .iter()
            .zip(&self.unhealthy)
            .map(|(s, &dead)| {
                if dead {
                    0
                } else {
                    s.spec().max_sortable_keys() as u128
                }
            })
            .collect();
        let total: u128 = weights.iter().sum();
        // mark_unhealthy never kills the last device, so the healthy
        // weight sum stays positive.
        debug_assert!(total > 0, "devices always have positive capacity");
        let mut shares: Vec<usize> = weights
            .iter()
            .map(|w| (n as u128 * w / total) as usize)
            .collect();
        let mut rest = n - shares.iter().sum::<usize>();
        // rest < (number of devices with nonzero weight), and the
        // descending sort puts zero-weight (unhealthy) devices last, so
        // the remainder never lands on a dead device.
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
        let mut i = 0;
        while rest > 0 {
            shares[order[i % order.len()]] += 1;
            rest -= 1;
            i += 1;
        }
        shares
    }

    /// Reset every member's ledger and allocation state.
    pub fn reset(&mut self) {
        for sim in &mut self.sims {
            sim.reset();
        }
    }
}

/// A checkout ledger over a fixed set of devices, shared between the
/// scheduler's workers: each worker-held sharded engine *leases* a
/// disjoint subset of the configured devices, so N concurrent engines
/// can never oversubscribe a device the way N independent
/// [`DevicePool`]s over the same model list would.
///
/// The registry hands out devices in configuration order and returns
/// them when the [`DeviceLease`] drops, so worker restarts (or a failed
/// engine construction) release their devices automatically. The handle
/// is cheap to clone; clones share one checkout ledger.
#[derive(Debug, Clone)]
pub struct DeviceRegistry {
    slots: Arc<Mutex<RegistrySlots>>,
}

/// Registry state under one lock: the checkout ledger plus per-slot
/// health. A slot marked unhealthy (via [`DeviceLease::mark_unhealthy`])
/// still returns on lease drop but is skipped by future checkouts, so a
/// restarted worker never re-leases a dead device.
#[derive(Debug)]
struct RegistrySlots {
    /// `Some(model)` = free, `None` = checked out.
    free: Vec<Option<GpuModel>>,
    /// `true` once the device at this slot was lost.
    unhealthy: Vec<bool>,
}

impl DeviceRegistry {
    /// New registry over a device list.
    pub fn new(models: Vec<GpuModel>) -> Self {
        let unhealthy = vec![false; models.len()];
        DeviceRegistry {
            slots: Arc::new(Mutex::new(RegistrySlots {
                free: models.into_iter().map(Some).collect(),
                unhealthy,
            })),
        }
    }

    /// Total number of devices (free or leased, healthy or not).
    pub fn total(&self) -> usize {
        self.slots.lock().unwrap().free.len()
    }

    /// Number of devices currently free *and* healthy.
    pub fn available(&self) -> usize {
        let slots = self.slots.lock().unwrap();
        slots
            .free
            .iter()
            .zip(&slots.unhealthy)
            .filter(|(s, &dead)| s.is_some() && !dead)
            .count()
    }

    /// Number of devices marked unhealthy so far.
    pub fn unhealthy_count(&self) -> usize {
        let slots = self.slots.lock().unwrap();
        slots.unhealthy.iter().filter(|&&d| d).count()
    }

    /// Lease `count` devices (the first free healthy ones, configuration
    /// order). Fails — rather than oversubscribing — when fewer are free.
    pub fn checkout(&self, count: usize) -> Result<DeviceLease> {
        if count == 0 {
            return Err(Error::InvalidParams(
                "a device lease needs at least one device".into(),
            ));
        }
        let mut slots = self.slots.lock().unwrap();
        let free: Vec<usize> = slots
            .free
            .iter()
            .enumerate()
            .filter(|&(i, s)| s.is_some() && !slots.unhealthy[i])
            .map(|(i, _)| i)
            .take(count)
            .collect();
        if free.len() < count {
            return Err(Error::InvalidParams(format!(
                "device registry oversubscribed: {count} requested, {} free of {}",
                free.len(),
                slots.free.len()
            )));
        }
        let models: Vec<GpuModel> = free
            .iter()
            .map(|&i| slots.free[i].take().expect("slot was free"))
            .collect();
        Ok(DeviceLease {
            registry: self.clone(),
            indices: free,
            models,
        })
    }

    /// The per-worker device share that partitions `total` devices over
    /// `workers` workers: worker `i` gets `total/workers`, with the
    /// remainder spread over the lowest-indexed workers. Zero when there
    /// are more workers than devices — the caller must reject that.
    pub fn share_for(worker: usize, workers: usize, total: usize) -> usize {
        if workers == 0 {
            return 0;
        }
        total / workers + usize::from(worker < total % workers)
    }
}

/// An exclusive lease on a subset of a [`DeviceRegistry`]'s devices.
/// Devices return to the registry on drop.
#[derive(Debug)]
pub struct DeviceLease {
    registry: DeviceRegistry,
    indices: Vec<usize>,
    models: Vec<GpuModel>,
}

impl DeviceLease {
    /// The leased device models.
    pub fn models(&self) -> &[GpuModel] {
        &self.models
    }

    /// Report the lease-local device `local` (index into
    /// [`DeviceLease::models`]) as lost. The registry slot is flagged so
    /// future checkouts — including a restarted worker's — skip it; the
    /// slot still returns on drop (it stays accounted, just unusable).
    pub fn mark_unhealthy(&self, local: usize) {
        if let Some(&slot) = self.indices.get(local) {
            self.registry.slots.lock().unwrap().unhealthy[slot] = true;
        }
    }
}

impl Drop for DeviceLease {
    fn drop(&mut self) {
        let mut slots = self.registry.slots.lock().unwrap();
        debug_assert!(self.indices.len() == self.models.len());
        for (&i, &model) in self.indices.iter().zip(&self.models) {
            slots.free[i] = Some(model);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_pool_rejected() {
        assert!(DevicePool::new(&[]).is_err());
        assert!(DevicePool::from_specs(vec![]).is_err());
    }

    #[test]
    fn default_pool_capacity_sums() {
        let pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        assert_eq!(pool.len(), 4);
        assert!(!pool.is_empty());
        let sum: usize = DevicePool::DEFAULT_DEVICES
            .iter()
            .map(|m| m.spec().max_sortable_keys())
            .sum();
        assert_eq!(pool.max_sortable_keys(), sum);
        // The pool breaks every single-device ceiling: > 512M keys.
        assert!(pool.max_sortable_keys() > 512 << 20);
    }

    #[test]
    fn shares_sum_and_weighting() {
        let pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        for n in [0usize, 1, 5, 1000, 1 << 20, (1 << 20) + 17] {
            let shares = pool.shares(n);
            assert_eq!(shares.len(), 4);
            assert_eq!(shares.iter().sum::<usize>(), n, "n={n}");
        }
        // Tesla (4 GB) holds twice the GTX 285 2 GB's share, pro rata.
        let shares = pool.shares(1 << 20);
        let tesla = shares[1] as f64;
        let gtx285 = shares[0] as f64;
        assert!((tesla / gtx285 - 2.0).abs() < 0.01, "{shares:?}");
    }

    #[test]
    fn shares_are_deterministic_and_monotone_in_capacity() {
        let pool = DevicePool::new(&[GpuModel::TeslaC1060, GpuModel::Gtx260]).unwrap();
        let a = pool.shares(12345);
        let b = pool.shares(12345);
        assert_eq!(a, b);
        assert!(a[0] > a[1], "bigger device gets the bigger shard: {a:?}");
    }

    #[test]
    fn equal_devices_split_evenly() {
        let pool =
            DevicePool::new(&[GpuModel::Gtx285_2G, GpuModel::Gtx285_2G]).unwrap();
        let shares = pool.shares(1001);
        assert_eq!(shares.iter().sum::<usize>(), 1001);
        assert!(shares[0].abs_diff(shares[1]) <= 1, "{shares:?}");
    }

    #[test]
    fn parse_device_lists() {
        assert_eq!(
            DevicePool::parse_list("gtx285,tesla"),
            Some(vec![GpuModel::Gtx285_2G, GpuModel::TeslaC1060])
        );
        assert_eq!(
            DevicePool::parse_list(" gtx260 , gtx285-1g "),
            Some(vec![GpuModel::Gtx260, GpuModel::Gtx285_1G])
        );
        assert_eq!(DevicePool::parse_list("gtx285,fermi"), None);
        assert_eq!(DevicePool::parse_list(""), None);
    }

    #[test]
    fn registry_checkout_is_exclusive_and_returns_on_drop() {
        let reg = DeviceRegistry::new(DevicePool::DEFAULT_DEVICES.to_vec());
        assert_eq!(reg.total(), 4);
        let a = reg.checkout(2).unwrap();
        assert_eq!(
            a.models(),
            &[GpuModel::Gtx285_2G, GpuModel::TeslaC1060],
            "leases follow configuration order"
        );
        let b = reg.checkout(2).unwrap();
        assert_eq!(b.models(), &[GpuModel::Gtx285_1G, GpuModel::Gtx260]);
        assert_eq!(reg.available(), 0);
        // A fifth device does not exist: no oversubscription.
        let err = reg.checkout(1).unwrap_err();
        assert!(err.to_string().contains("oversubscribed"), "{err}");
        drop(a);
        assert_eq!(reg.available(), 2);
        let c = reg.checkout(2).unwrap();
        assert_eq!(c.models(), &[GpuModel::Gtx285_2G, GpuModel::TeslaC1060]);
        // Zero-device leases are rejected.
        assert!(reg.checkout(0).is_err());
    }

    #[test]
    fn worker_shares_partition_exactly() {
        for (workers, total) in [(1usize, 4usize), (2, 4), (3, 4), (4, 4), (2, 5), (3, 8)] {
            let sum: usize = (0..workers)
                .map(|w| DeviceRegistry::share_for(w, workers, total))
                .sum();
            assert_eq!(sum, total, "workers={workers} total={total}");
            for w in 0..workers {
                assert!(DeviceRegistry::share_for(w, workers, total) >= total / workers);
            }
        }
        assert_eq!(DeviceRegistry::share_for(0, 0, 4), 0);
        // More workers than devices: some worker's share is zero.
        assert_eq!(DeviceRegistry::share_for(4, 5, 4), 0);
    }

    #[test]
    fn unhealthy_devices_get_zero_share_and_no_capacity() {
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let healthy_cap = pool.max_sortable_keys();
        assert_eq!(pool.healthy_count(), 4);
        pool.mark_unhealthy(1).unwrap(); // Tesla, the biggest card
        assert!(!pool.is_healthy(1));
        assert_eq!(pool.healthy_count(), 3);
        assert_eq!(pool.healthy_indices(), vec![0, 2, 3]);
        assert_eq!(
            pool.max_sortable_keys(),
            healthy_cap - GpuModel::TeslaC1060.spec().max_sortable_keys()
        );
        for n in [0usize, 1, 1000, (1 << 20) + 17] {
            let shares = pool.shares(n);
            assert_eq!(shares.len(), 4);
            assert_eq!(shares[1], 0, "dead device got keys: {shares:?}");
            assert_eq!(shares.iter().sum::<usize>(), n);
        }
        // Health survives reset (a dead device stays dead across jobs)…
        pool.reset();
        assert_eq!(pool.healthy_count(), 3);
        // …until an explicit restore.
        pool.restore_health();
        assert_eq!(pool.healthy_count(), 4);
        assert_eq!(pool.max_sortable_keys(), healthy_cap);
    }

    #[test]
    fn last_healthy_device_cannot_be_marked() {
        let mut pool = DevicePool::new(&[GpuModel::Gtx260, GpuModel::Gtx260]).unwrap();
        pool.mark_unhealthy(0).unwrap();
        // Re-marking an already-dead device is a no-op, not an error.
        pool.mark_unhealthy(0).unwrap();
        let err = pool.mark_unhealthy(1).unwrap_err();
        assert!(err.to_string().contains("last healthy"), "{err}");
        assert!(pool.is_healthy(1));
        assert_eq!(pool.shares(100), vec![0, 100]);
    }

    #[test]
    fn registry_skips_unhealthy_slots() {
        let reg = DeviceRegistry::new(DevicePool::DEFAULT_DEVICES.to_vec());
        let lease = reg.checkout(2).unwrap();
        assert_eq!(reg.available(), 2);
        // Local device 1 of the lease = registry slot 1 (Tesla).
        lease.mark_unhealthy(1);
        assert_eq!(reg.unhealthy_count(), 1);
        drop(lease);
        // The dead slot returned but is not checkable-out.
        assert_eq!(reg.total(), 4);
        assert_eq!(reg.available(), 3);
        let next = reg.checkout(3).unwrap();
        assert_eq!(
            next.models(),
            &[GpuModel::Gtx285_2G, GpuModel::Gtx285_1G, GpuModel::Gtx260],
            "checkout must skip the dead Tesla slot"
        );
        assert!(reg.checkout(1).is_err(), "only the dead slot remains");
        // Out-of-range local index is ignored.
        next.mark_unhealthy(99);
        assert_eq!(reg.unhealthy_count(), 1);
    }

    #[test]
    fn reset_clears_all_members() {
        let mut pool = DevicePool::new(&[GpuModel::Gtx260, GpuModel::Gtx260]).unwrap();
        let a = pool.sim_mut(0).alloc(64).unwrap();
        pool.sim_mut(0).free(a);
        assert_eq!(pool.sims()[0].peak_bytes(), 64);
        pool.reset();
        assert_eq!(pool.sims()[0].peak_bytes(), 0);
        assert_eq!(pool.spec(1).name, "GTX 260");
    }
}

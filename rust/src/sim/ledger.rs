//! The traffic ledger: an exact record of the GPU work an algorithm
//! generates, kept per kernel launch.
//!
//! Every algorithm in [`crate::algos`] calls [`Ledger::begin_kernel`] /
//! [`Ledger::end_kernel`] around each conceptual GPU kernel and records
//! the traffic that kernel would generate:
//!
//! * **coalesced bytes** — global-memory traffic in contiguous,
//!   transaction-aligned arrays (the paper's "parallel coalesced data
//!   read/write", §4 Step 8);
//! * **scattered transactions** — global accesses that each occupy a full
//!   [`crate::sim::spec::MEM_TRANSACTION_BYTES`] segment regardless of
//!   payload (uncoalesced access, the failure mode §2 warns about);
//! * **shared-memory ops** — per-core accesses to the SM-local 16 KB
//!   memory (an order of magnitude faster than global, §2);
//! * **compute ops** — scalar operations (compare-exchange counts, index
//!   arithmetic);
//! * **divergent ops** — operations executed under a data-dependent
//!   branch, which the SIMT model serializes (§2's conditional-branching
//!   discussion); the cost model charges these at a multiple.
//!
//! Ledgers add, so a full Algorithm-1 run is the sum of its steps; the
//! per-step split regenerates the paper's Figure 5.

use std::collections::BTreeMap;

/// Which conceptual GPU kernel produced a launch record. Used by the cost
/// model to apply per-class efficiency factors and by reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelClass {
    /// Step 2: bitonic sort of one tile per SM in shared memory.
    LocalSort,
    /// Steps 3 & 5: equidistant sample extraction.
    Sample,
    /// Steps 4 & 9: bitonic merge passes in global memory.
    GlobalBitonic,
    /// Step 6: parallel binary search of global samples in each tile.
    SampleIndex,
    /// Step 7: column-sum / prefix / update passes (Figure 1).
    PrefixSum,
    /// Step 8: coalesced bucket relocation.
    Relocation,
    /// Randomized sample sort: bucket-finding pass (traverses the
    /// search tree of splitters).
    BucketFind,
    /// Randomized sample sort / quicksort-style scatter with atomics.
    ScatterAtomic,
    /// Thrust Merge: odd-even merge / two-way merge passes.
    Merge,
    /// Radix sort: digit histogram / scan / scatter passes.
    RadixPass,
    /// Small sequential or single-block work (e.g. prefix over column
    /// sums on one SM).
    SingleBlock,
    /// Host↔device or other bookkeeping transfers.
    Transfer,
}

/// One kernel launch's recorded traffic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelStats {
    /// Kernel class (cost-model behaviour).
    pub class: KernelClass,
    /// Algorithm-1 step this launch belongs to (1–9), or 0 for
    /// baseline/other work. Drives the Figure 5 per-step breakdown.
    pub step: u8,
    /// Thread blocks launched.
    pub blocks: u64,
    /// Threads per block.
    pub threads_per_block: u32,
    /// Contiguous global-memory traffic in bytes (reads + writes).
    pub coalesced_bytes: u64,
    /// Non-contiguous global accesses, each costing a full memory
    /// transaction.
    pub scattered_transactions: u64,
    /// Shared-memory accesses.
    pub smem_ops: u64,
    /// Scalar compute operations.
    pub compute_ops: u64,
    /// Compute operations under divergent branches (serialized by SIMT).
    pub divergent_ops: u64,
}

impl KernelStats {
    fn new(class: KernelClass, blocks: u64, threads_per_block: u32) -> Self {
        KernelStats {
            class,
            step: 0,
            blocks,
            threads_per_block,
            coalesced_bytes: 0,
            scattered_transactions: 0,
            smem_ops: 0,
            compute_ops: 0,
            divergent_ops: 0,
        }
    }

    /// Total global-memory bytes including the transaction-granularity
    /// penalty on scattered accesses.
    pub fn effective_global_bytes(&self) -> u64 {
        self.coalesced_bytes
            + self.scattered_transactions * crate::sim::spec::MEM_TRANSACTION_BYTES as u64
    }
}

/// Aggregated traffic for one Algorithm-1 step (or a whole run).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepLedger {
    /// Number of kernel launches.
    pub launches: u64,
    /// Total thread blocks.
    pub blocks: u64,
    /// Coalesced global bytes.
    pub coalesced_bytes: u64,
    /// Scattered transactions.
    pub scattered_transactions: u64,
    /// Shared-memory ops.
    pub smem_ops: u64,
    /// Compute ops.
    pub compute_ops: u64,
    /// Divergent (serialized) ops.
    pub divergent_ops: u64,
}

impl StepLedger {
    /// Fold one launch into the aggregate.
    pub fn absorb(&mut self, k: &KernelStats) {
        self.launches += 1;
        self.blocks += k.blocks;
        self.coalesced_bytes += k.coalesced_bytes;
        self.scattered_transactions += k.scattered_transactions;
        self.smem_ops += k.smem_ops;
        self.compute_ops += k.compute_ops;
        self.divergent_ops += k.divergent_ops;
    }

    /// Effective global bytes (coalesced + transaction-padded scattered).
    pub fn effective_global_bytes(&self) -> u64 {
        self.coalesced_bytes
            + self.scattered_transactions * crate::sim::spec::MEM_TRANSACTION_BYTES as u64
    }
}

/// The full launch-by-launch record of a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Ledger {
    kernels: Vec<KernelStats>,
    current: Option<KernelStats>,
}

impl Ledger {
    /// Begin recording a kernel launch. Panics if a launch is already
    /// open — kernels never nest on a GPU stream.
    pub fn begin_kernel(&mut self, class: KernelClass, blocks: u64, threads_per_block: u32) {
        assert!(
            self.current.is_none(),
            "begin_kernel while a kernel is open"
        );
        self.current = Some(KernelStats::new(class, blocks, threads_per_block));
    }

    /// Tag the open launch with an Algorithm-1 step number (1–9).
    pub fn tag_step(&mut self, step: u8) {
        self.cur().step = step;
    }

    /// Record contiguous global-memory traffic (bytes, reads+writes).
    pub fn add_coalesced(&mut self, bytes: u64) {
        self.cur().coalesced_bytes += bytes;
    }

    /// Record `n` scattered global accesses.
    pub fn add_scattered(&mut self, transactions: u64) {
        self.cur().scattered_transactions += transactions;
    }

    /// Record shared-memory accesses.
    pub fn add_smem(&mut self, ops: u64) {
        self.cur().smem_ops += ops;
    }

    /// Record scalar compute operations.
    pub fn add_compute(&mut self, ops: u64) {
        self.cur().compute_ops += ops;
    }

    /// Record compute operations executed under divergent branches.
    pub fn add_divergent(&mut self, ops: u64) {
        self.cur().divergent_ops += ops;
    }

    /// Close the open launch.
    pub fn end_kernel(&mut self) {
        let k = self
            .current
            .take()
            .expect("end_kernel without begin_kernel");
        self.kernels.push(k);
    }

    /// Convenience: record a whole launch at once.
    pub fn record(&mut self, stats: KernelStats) {
        assert!(self.current.is_none(), "record while a kernel is open");
        self.kernels.push(stats);
    }

    fn cur(&mut self) -> &mut KernelStats {
        self.current
            .as_mut()
            .expect("ledger op outside begin/end_kernel")
    }

    /// All recorded launches.
    pub fn kernels(&self) -> &[KernelStats] {
        &self.kernels
    }

    /// Number of closed launches.
    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    /// Aggregate everything into one [`StepLedger`].
    pub fn total(&self) -> StepLedger {
        let mut t = StepLedger::default();
        for k in &self.kernels {
            t.absorb(k);
        }
        t
    }

    /// Aggregate per Algorithm-1 step (key = step number; 0 = untagged).
    pub fn by_step(&self) -> BTreeMap<u8, StepLedger> {
        let mut m: BTreeMap<u8, StepLedger> = BTreeMap::new();
        for k in &self.kernels {
            m.entry(k.step).or_default().absorb(k);
        }
        m
    }

    /// Aggregate per kernel class.
    pub fn by_class(&self) -> BTreeMap<KernelClass, StepLedger> {
        let mut m: BTreeMap<KernelClass, StepLedger> = BTreeMap::new();
        for k in &self.kernels {
            m.entry(k.class).or_default().absorb(k);
        }
        m
    }

    /// Append another ledger's launches (used when assembling a run from
    /// phases executed on different engines).
    pub fn extend_from(&mut self, other: &Ledger) {
        assert!(other.current.is_none(), "cannot merge a ledger with an open kernel");
        self.kernels.extend(other.kernels.iter().cloned());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_launch(step: u8, bytes: u64) -> KernelStats {
        KernelStats {
            class: KernelClass::LocalSort,
            step,
            blocks: 4,
            threads_per_block: 512,
            coalesced_bytes: bytes,
            scattered_transactions: 2,
            smem_ops: 100,
            compute_ops: 50,
            divergent_ops: 5,
        }
    }

    #[test]
    fn begin_record_end() {
        let mut l = Ledger::default();
        l.begin_kernel(KernelClass::LocalSort, 16, 512);
        l.tag_step(2);
        l.add_coalesced(1024);
        l.add_smem(2048);
        l.add_compute(512);
        l.end_kernel();
        assert_eq!(l.kernel_count(), 1);
        let k = &l.kernels()[0];
        assert_eq!(k.step, 2);
        assert_eq!(k.coalesced_bytes, 1024);
        assert_eq!(k.smem_ops, 2048);
    }

    #[test]
    #[should_panic(expected = "begin_kernel while a kernel is open")]
    fn no_nesting() {
        let mut l = Ledger::default();
        l.begin_kernel(KernelClass::LocalSort, 1, 1);
        l.begin_kernel(KernelClass::Sample, 1, 1);
    }

    #[test]
    #[should_panic(expected = "end_kernel without begin_kernel")]
    fn end_requires_begin() {
        let mut l = Ledger::default();
        l.end_kernel();
    }

    #[test]
    fn step_aggregation() {
        let mut l = Ledger::default();
        l.record(sample_launch(2, 100));
        l.record(sample_launch(2, 200));
        l.record(sample_launch(9, 300));
        let by = l.by_step();
        assert_eq!(by[&2].launches, 2);
        assert_eq!(by[&2].coalesced_bytes, 300);
        assert_eq!(by[&9].coalesced_bytes, 300);
        let t = l.total();
        assert_eq!(t.launches, 3);
        assert_eq!(t.coalesced_bytes, 600);
        assert_eq!(t.scattered_transactions, 6);
    }

    #[test]
    fn effective_bytes_pads_scattered() {
        let k = sample_launch(0, 100);
        // 100 + 2 * 64.
        assert_eq!(k.effective_global_bytes(), 100 + 2 * 64);
    }

    #[test]
    fn merge_ledgers() {
        let mut a = Ledger::default();
        a.record(sample_launch(2, 100));
        let mut b = Ledger::default();
        b.record(sample_launch(9, 50));
        a.extend_from(&b);
        assert_eq!(a.kernel_count(), 2);
        assert_eq!(a.total().coalesced_bytes, 150);
    }

    #[test]
    fn class_aggregation() {
        let mut l = Ledger::default();
        l.record(sample_launch(2, 10));
        let mut k = sample_launch(9, 20);
        k.class = KernelClass::GlobalBitonic;
        l.record(k);
        let by = l.by_class();
        assert_eq!(by[&KernelClass::LocalSort].coalesced_bytes, 10);
        assert_eq!(by[&KernelClass::GlobalBitonic].coalesced_bytes, 20);
    }
}

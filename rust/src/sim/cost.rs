//! Cost model: traffic ledger × hardware spec → estimated milliseconds.
//!
//! Each kernel launch is charged a roofline time,
//!
//! ```text
//! t = launch_overhead + max(t_mem, t_compute, t_smem) / occupancy
//! t_mem     = effective_global_bytes / (peak_bandwidth × coalesced_efficiency)
//! t_compute = (compute_ops × instructions_per_op
//!              + divergent_ops × instructions_per_op × divergence_penalty)
//!             / (cores × core_clock)
//! t_smem    = smem_ops / (cores × core_clock × smem_throughput)
//! ```
//!
//! and a run is the sum over launches (kernels on one CUDA stream are
//! serial). `occupancy = min(1, blocks / SMs)` captures the tail effect
//! when a launch cannot fill the device.
//!
//! Rationale: the paper demonstrates GPU BUCKET SORT is **bandwidth
//! bound** (§5 — device ordering follows Table 1 memory bandwidth), and
//! all its kernels are branch-free streaming passes, so a per-launch
//! bandwidth/compute roofline with an explicit divergence penalty (the
//! §2 SIMT serialization discussion) captures exactly the effects the
//! paper reasons about. Constants below were calibrated once so that the
//! simulated GTX 285 sorts 32M uniform keys in ≈230 ms — the throughput
//! ballpark both this paper and Leischner et al. [9] report — and are
//! **never tuned per-experiment**; every figure uses the same constants
//! (see EXPERIMENTS.md §Calibration).

use super::ledger::{KernelStats, Ledger};
use super::spec::GpuSpec;
use std::collections::BTreeMap;

/// Tunable constants of the cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct CostParams {
    /// Fixed cost per kernel launch in ms (driver + scheduling). 10–15 µs
    /// was typical of the 2009 CUDA stack; we charge 10 µs.
    pub launch_overhead_ms: f64,
    /// Fraction of nameplate bandwidth achieved by fully coalesced
    /// streaming access.
    pub coalesced_efficiency: f64,
    /// Machine instructions per recorded semantic operation (a recorded
    /// "compare-exchange" costs several ALU/LSU instructions: compare,
    /// two selects, index arithmetic).
    pub instructions_per_op: f64,
    /// Serialization multiplier for operations under divergent branches
    /// (§2: branches execute in sequence within a warp).
    pub divergence_penalty: f64,
    /// Shared-memory accesses per core per clock (1.0 = one access per
    /// core-cycle aggregate; bank conflicts would lower it).
    pub smem_throughput: f64,
    /// Fraction of peak scalar throughput sustained by well-shaped SIMT
    /// code (instruction mix, dual-issue limits).
    pub simt_efficiency: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            launch_overhead_ms: 0.010,
            coalesced_efficiency: 0.75,
            instructions_per_op: 6.0,
            divergence_penalty: 8.0,
            smem_throughput: 1.0,
            simt_efficiency: 0.9,
        }
    }
}

/// A spec + params pair, ready to price ledgers.
#[derive(Debug, Clone)]
pub struct CostModel {
    spec: GpuSpec,
    params: CostParams,
}

impl CostModel {
    /// Build a cost model with explicit parameters.
    pub fn new(spec: GpuSpec, params: CostParams) -> Self {
        CostModel { spec, params }
    }

    /// Build a cost model with the calibrated default parameters.
    pub fn default_params(spec: &GpuSpec) -> Self {
        CostModel::new(spec.clone(), CostParams::default())
    }

    /// The spec being modelled.
    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }

    /// The parameters in use.
    pub fn params(&self) -> &CostParams {
        &self.params
    }

    /// Estimated milliseconds for a single kernel launch.
    pub fn kernel_ms(&self, k: &KernelStats) -> f64 {
        let p = &self.params;
        let occupancy = if k.blocks == 0 {
            1.0
        } else {
            (k.blocks as f64 / self.spec.sm_count as f64).min(1.0)
        };

        let t_mem = k.effective_global_bytes() as f64
            / (self.spec.bandwidth_bytes_per_ms() * p.coalesced_efficiency);

        let instr = k.compute_ops as f64 * p.instructions_per_op
            + k.divergent_ops as f64 * p.instructions_per_op * p.divergence_penalty;
        let t_compute = instr / (self.spec.compute_ops_per_ms() * p.simt_efficiency);

        let t_smem =
            k.smem_ops as f64 / (self.spec.shared_ops_per_ms() * p.smem_throughput);

        p.launch_overhead_ms + t_mem.max(t_compute).max(t_smem) / occupancy
    }

    /// Estimated milliseconds for a whole ledger (launches are serial on
    /// one stream).
    pub fn ledger_ms(&self, ledger: &Ledger) -> f64 {
        ledger.kernels().iter().map(|k| self.kernel_ms(k)).sum()
    }

    /// Per-Algorithm-1-step estimated milliseconds (Figure 5's series).
    pub fn step_ms(&self, ledger: &Ledger) -> BTreeMap<u8, f64> {
        let mut m: BTreeMap<u8, f64> = BTreeMap::new();
        for k in ledger.kernels() {
            *m.entry(k.step).or_insert(0.0) += self.kernel_ms(k);
        }
        m
    }

    /// Sorting rate in million keys per second for `n` keys taking
    /// `ms` — the paper's §5 "fixed sorting rate" metric.
    pub fn sort_rate_mkeys_s(n: usize, ms: f64) -> f64 {
        if ms <= 0.0 {
            return 0.0;
        }
        n as f64 / ms / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ledger::KernelClass;
    use crate::sim::spec::GpuModel;

    fn stats(bytes: u64, ops: u64, blocks: u64) -> KernelStats {
        KernelStats {
            class: KernelClass::GlobalBitonic,
            step: 4,
            blocks,
            threads_per_block: 512,
            coalesced_bytes: bytes,
            scattered_transactions: 0,
            smem_ops: 0,
            compute_ops: ops,
            divergent_ops: 0,
        }
    }

    #[test]
    fn bandwidth_bound_kernel() {
        // A pure streaming kernel: 149 MB on a GTX 285 at 149 GB/s and
        // 0.75 efficiency ≈ 1.333 ms + overhead.
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let t = m.kernel_ms(&stats(149_000_000, 0, 1000));
        assert!((t - (0.010 + 1.0 / 0.75)).abs() < 1e-3, "t={t}");
    }

    #[test]
    fn compute_bound_kernel() {
        // 155.52e6 ops/ms peak; 10e6 recorded ops * 6 instr / 0.9 eff.
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let t = m.kernel_ms(&stats(0, 10_000_000, 1000));
        let expect = 0.010 + 10e6 * 6.0 / (354.24e6 * 0.9);
        assert!((t - expect).abs() < 1e-6, "t={t} expect={expect}");
    }

    #[test]
    fn divergence_is_penalized() {
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let mut k = stats(0, 1_000_000, 1000);
        let base = m.kernel_ms(&k);
        k.divergent_ops = 1_000_000;
        let with_div = m.kernel_ms(&k);
        // Divergent ops cost divergence_penalty× the straight-line ops.
        assert!(with_div > base * 5.0, "base={base} div={with_div}");
    }

    #[test]
    fn scattered_access_is_penalized() {
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let mut k = stats(4_000_000, 0, 1000);
        let coalesced = m.kernel_ms(&k);
        // Same payload as 1M scattered 4-byte accesses → 64 B each.
        k.coalesced_bytes = 0;
        k.scattered_transactions = 1_000_000;
        let scattered = m.kernel_ms(&k);
        assert!(scattered > coalesced * 10.0);
    }

    #[test]
    fn low_occupancy_stretches_time() {
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let full = m.kernel_ms(&stats(149_000_000, 0, 30));
        let single_block = m.kernel_ms(&stats(149_000_000, 0, 1));
        assert!(single_block > full * 20.0);
    }

    #[test]
    fn device_ordering_follows_bandwidth() {
        // The paper's Figure 4 ordering for a bandwidth-bound ledger:
        // GTX 285 < GTX 260 < Tesla C1060 (time), §5.
        let k = stats(1_000_000_000, 0, 10_000);
        let t285 = CostModel::default_params(&GpuModel::Gtx285_2G.spec()).kernel_ms(&k);
        let t260 = CostModel::default_params(&GpuModel::Gtx260.spec()).kernel_ms(&k);
        let tesla = CostModel::default_params(&GpuModel::TeslaC1060.spec()).kernel_ms(&k);
        assert!(t285 < t260, "285={t285} 260={t260}");
        assert!(t260 < tesla, "260={t260} tesla={tesla}");
    }

    #[test]
    fn ledger_sums_and_step_split() {
        let m = CostModel::default_params(&GpuModel::Gtx285_2G.spec());
        let mut l = Ledger::default();
        let mut a = stats(1_000_000, 0, 100);
        a.step = 2;
        let mut b = stats(2_000_000, 0, 100);
        b.step = 9;
        l.record(a.clone());
        l.record(b.clone());
        let total = m.ledger_ms(&l);
        let split = m.step_ms(&l);
        assert!((total - (m.kernel_ms(&a) + m.kernel_ms(&b))).abs() < 1e-12);
        assert!((split[&2] + split[&9] - total).abs() < 1e-12);
        assert!(split[&9] > split[&2]);
    }

    #[test]
    fn sort_rate() {
        // 32M keys in 250 ms = 128 Mkeys/s.
        let r = CostModel::sort_rate_mkeys_s(32 << 20, 250.0);
        assert!((r - 134.2).abs() < 1.0, "r={r}");
    }
}

//! Deterministic fault injection.
//!
//! A [`FaultPlan`] is a small, versioned JSON document (loaded via
//! `--fault-plan` / `config.fault_plan`) describing *which* faults to
//! inject *where* and *when*. The plan compiles into a [`FaultInjector`]
//! that the service threads through [`crate::ExecContext`] and the net
//! tier; instrumented points ask the injector "should I fail here?" and
//! get a deterministic answer:
//!
//! * **Attempt-counted, not wall-clock.** Rules trigger on the N-th
//!   eligible hit of an instrumented point (`after`/`count`), so a
//!   schedule replays exactly — no timing races decide whether a fault
//!   lands.
//! * **Seeded.** Rules with `probability < 1` draw from a
//!   [`Rng`](crate::util::Rng) seeded by the plan, so even probabilistic
//!   schedules replay bit-for-bit when the sequence of injector calls is
//!   deterministic (single worker). Multi-worker sweeps should stick to
//!   `probability: 1.0` (the default), which never consumes randomness.
//! * **Zero overhead when absent.** The injector lives behind an
//!   `Option<Arc<…>>`; with no plan loaded every instrumented point is a
//!   single `None` check.
//!
//! Plan format (`version` is required and must be `1`):
//!
//! ```json
//! {
//!   "version": 1,
//!   "seed": 42,
//!   "rules": [
//!     { "point": "device_lost", "target": 3, "after": 0, "count": 1 },
//!     { "point": "slow_device", "delay_ms": 5, "probability": 0.5 }
//!   ]
//! }
//! ```
//!
//! Points: `device_lost`, `device_oom`, `slow_device` (paces the worker
//! by `delay_ms` per job), `worker_panic`, `socket_cut`, `frame_corrupt`,
//! `node_down` (a whole sort-server process dies — exercises cluster
//! failover).
//! `target` restricts a rule to one device/worker/connection index;
//! omitted means "any". `after` skips the first N eligible hits, `count`
//! bounds how many times the rule fires (default 1).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::{Json, Rng};

/// An instrumented failure point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultPoint {
    /// The device drops off the bus mid-step → [`Error::DeviceLost`],
    /// retried by sharded failover.
    DeviceLost,
    /// A mid-step device allocation fails → [`Error::DeviceOom`], fatal
    /// for the request (capacity is a property of the plan, not luck).
    DeviceOom,
    /// The worker paces itself by `delay_ms` per job — models a thermal-
    /// throttled or contended device without failing anything.
    SlowDevice,
    /// The kernel job panics inside the engine — must be contained at
    /// the worker boundary ([`Error::Internal`] for that request only).
    WorkerPanic,
    /// The client-side socket is severed mid-stream — exercises
    /// reconnect + idempotent resubmit.
    SocketCut,
    /// A frame leaving the client is corrupted (payload bit-flip) — the
    /// server must reject it by CRC and the stream recovers.
    FrameCorrupt,
    /// A whole sort-server process dies abruptly (crash, OOM-kill,
    /// power loss) — exercises registry eviction and cluster failover.
    NodeDown,
}

impl FaultPoint {
    /// All points, in the order they appear in docs and counters.
    pub const ALL: [FaultPoint; 7] = [
        FaultPoint::DeviceLost,
        FaultPoint::DeviceOom,
        FaultPoint::SlowDevice,
        FaultPoint::WorkerPanic,
        FaultPoint::SocketCut,
        FaultPoint::FrameCorrupt,
        FaultPoint::NodeDown,
    ];

    /// Stable snake_case name used in plan JSON and metrics counters.
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultPoint::DeviceLost => "device_lost",
            FaultPoint::DeviceOom => "device_oom",
            FaultPoint::SlowDevice => "slow_device",
            FaultPoint::WorkerPanic => "worker_panic",
            FaultPoint::SocketCut => "socket_cut",
            FaultPoint::FrameCorrupt => "frame_corrupt",
            FaultPoint::NodeDown => "node_down",
        }
    }

    fn parse(s: &str) -> Result<FaultPoint> {
        FaultPoint::ALL
            .iter()
            .copied()
            .find(|p| p.as_str() == s)
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown fault point {s:?} (expected one of: {})",
                    FaultPoint::ALL.map(|p| p.as_str()).join(", ")
                ))
            })
    }
}

/// The device-level faults an instrumented step can receive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceFault {
    /// Treat the device as gone: [`Error::DeviceLost`].
    Lost,
    /// Treat the next allocation as failed: [`Error::DeviceOom`].
    Oom,
}

/// One injection rule: fire `count` times at `point` (optionally only on
/// `target`), skipping the first `after` eligible hits, each hit gated
/// by `probability`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    /// Which instrumented point this rule arms.
    pub point: FaultPoint,
    /// Restrict to one device/worker/connection index; `None` = any.
    pub target: Option<usize>,
    /// Skip this many eligible hits before becoming armed.
    pub after: u64,
    /// Fire at most this many times (default 1).
    pub count: u64,
    /// Chance each armed hit actually fires (default 1.0 — no RNG draw).
    pub probability: f64,
    /// Pacing for `slow_device`; ignored by other points.
    pub delay_ms: u64,
}

/// A parsed, validated fault plan. Compile it into a live injector with
/// [`FaultPlan::injector`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Plan format version — always 1 today.
    pub version: u64,
    /// Seed for probabilistic rules.
    pub seed: u64,
    /// The injection rules, in plan order.
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse and validate a plan from JSON text.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let j = Json::parse(text).map_err(|e| Error::Config(format!("fault plan: {e}")))?;
        let version = j
            .req("version")
            .ok()
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Config("fault plan: missing numeric \"version\"".into()))?;
        if version != 1 {
            return Err(Error::Config(format!(
                "fault plan: unsupported version {version} (this build understands 1)"
            )));
        }
        let seed = j.get("seed").and_then(Json::as_u64).unwrap_or(0);
        let rules_json = j
            .get("rules")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("fault plan: missing \"rules\" array".into()))?;
        let mut rules = Vec::with_capacity(rules_json.len());
        for (i, r) in rules_json.iter().enumerate() {
            let at = |m: String| Error::Config(format!("fault plan rule {i}: {m}"));
            let point_name = r
                .get("point")
                .and_then(Json::as_str)
                .ok_or_else(|| at("missing string \"point\"".into()))?;
            let point = FaultPoint::parse(point_name)?;
            let target = match r.get("target") {
                None => None,
                Some(t) => Some(
                    t.as_usize()
                        .ok_or_else(|| at("\"target\" must be a non-negative integer".into()))?,
                ),
            };
            let after = r.get("after").and_then(Json::as_u64).unwrap_or(0);
            let count = r.get("count").and_then(Json::as_u64).unwrap_or(1);
            if count == 0 {
                return Err(at("\"count\" must be >= 1 (omit the rule instead)".into()));
            }
            let probability = r.get("probability").and_then(Json::as_f64).unwrap_or(1.0);
            if !(0.0..=1.0).contains(&probability) {
                return Err(at(format!(
                    "\"probability\" must be in [0, 1], got {probability}"
                )));
            }
            let delay_ms = r.get("delay_ms").and_then(Json::as_u64).unwrap_or(0);
            if point == FaultPoint::SlowDevice && delay_ms == 0 {
                return Err(at("slow_device requires \"delay_ms\" >= 1".into()));
            }
            rules.push(FaultRule {
                point,
                target,
                after,
                count,
                probability,
                delay_ms,
            });
        }
        Ok(FaultPlan {
            version,
            seed,
            rules,
        })
    }

    /// Load and validate a plan from a JSON file.
    pub fn load(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("fault plan {path:?}: {e}")))?;
        FaultPlan::parse(&text)
    }

    /// Resolve a `--fault-plan` / `config.fault_plan` value: the empty
    /// string means "no plan" (and costs nothing at runtime); anything
    /// else must be a readable, valid plan file.
    pub fn resolve(spec: &str) -> Result<Option<FaultPlan>> {
        if spec.is_empty() {
            return Ok(None);
        }
        FaultPlan::load(spec).map(Some)
    }

    /// Serialize back to plan JSON (round-trips through [`parse`]).
    ///
    /// [`parse`]: FaultPlan::parse
    pub fn to_json(&self) -> Json {
        let rules = self
            .rules
            .iter()
            .map(|r| {
                let mut pairs = vec![("point", Json::str(r.point.as_str()))];
                if let Some(t) = r.target {
                    pairs.push(("target", Json::num(t as f64)));
                }
                pairs.push(("after", Json::num(r.after as f64)));
                pairs.push(("count", Json::num(r.count as f64)));
                pairs.push(("probability", Json::num(r.probability)));
                pairs.push(("delay_ms", Json::num(r.delay_ms as f64)));
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("rules", Json::Arr(rules)),
        ])
    }

    /// Compile the plan into a live, shareable injector.
    pub fn injector(&self) -> Arc<FaultInjector> {
        Arc::new(FaultInjector::new(self.clone()))
    }
}

struct RuleState {
    rule: FaultRule,
    /// Eligible hits seen so far (matching point + target).
    hits: u64,
    /// Times this rule actually fired.
    fired: u64,
}

struct State {
    rng: Rng,
    rules: Vec<RuleState>,
    /// Count of injected faults per point name — exported into the
    /// metrics snapshot as `fault_injected_<point>`.
    injected: BTreeMap<&'static str, u64>,
}

/// Live injector compiled from a [`FaultPlan`]. Instrumented points call
/// the `device_fault` / `worker_panic` / … probes; each probe consults
/// the armed rules under a single short lock.
pub struct FaultInjector {
    plan: FaultPlan,
    state: Mutex<State>,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector")
            .field("rules", &self.plan.rules.len())
            .field("seed", &self.plan.seed)
            .finish_non_exhaustive()
    }
}

impl FaultInjector {
    /// Build a fresh injector (all rule counters at zero).
    pub fn new(plan: FaultPlan) -> Self {
        let state = State {
            rng: Rng::new(plan.seed ^ 0x6661756c745f7267), // "fault_rg"
            rules: plan
                .rules
                .iter()
                .map(|r| RuleState {
                    rule: r.clone(),
                    hits: 0,
                    fired: 0,
                })
                .collect(),
            injected: BTreeMap::new(),
        };
        FaultInjector {
            plan,
            state: Mutex::new(state),
        }
    }

    /// The plan this injector was compiled from.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Core probe: does any rule at `point` fire for `target`? Returns
    /// the firing rule's `delay_ms` when it does. Exactly one rule fires
    /// per probe (the first armed match, in plan order).
    fn probe(&self, point: FaultPoint, target: usize) -> Option<u64> {
        // The injector is shared read-mostly state guarded by one short
        // lock; a poisoned lock here can only come from a panic *inside
        // this module*, which has no unwind paths while holding it.
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let State {
            rng,
            rules,
            injected,
        } = &mut *st;
        for rs in rules.iter_mut() {
            if rs.rule.point != point {
                continue;
            }
            if rs.rule.target.is_some_and(|t| t != target) {
                continue;
            }
            rs.hits += 1;
            if rs.hits <= rs.rule.after || rs.fired >= rs.rule.count {
                continue;
            }
            // probability 1.0 never consumes randomness, so fully
            // deterministic plans stay order-independent across workers.
            if rs.rule.probability < 1.0 && rng.next_f64() >= rs.rule.probability {
                continue;
            }
            rs.fired += 1;
            *injected.entry(point.as_str()).or_insert(0) += 1;
            return Some(rs.rule.delay_ms);
        }
        None
    }

    /// Should the step running on `device` see a device-level fault?
    /// Lost takes precedence over OOM when both are armed.
    pub fn device_fault(&self, device: usize) -> Option<DeviceFault> {
        if self.probe(FaultPoint::DeviceLost, device).is_some() {
            return Some(DeviceFault::Lost);
        }
        if self.probe(FaultPoint::DeviceOom, device).is_some() {
            return Some(DeviceFault::Oom);
        }
        None
    }

    /// Pacing delay (ms) for this worker's current job, if a
    /// `slow_device` rule fires.
    pub fn slow_device_ms(&self, worker: usize) -> Option<u64> {
        self.probe(FaultPoint::SlowDevice, worker)
    }

    /// Should the kernel job on `worker` panic?
    pub fn worker_panic(&self, worker: usize) -> bool {
        self.probe(FaultPoint::WorkerPanic, worker).is_some()
    }

    /// Should connection `conn` sever its socket before the next write?
    pub fn socket_cut(&self, conn: usize) -> bool {
        self.probe(FaultPoint::SocketCut, conn).is_some()
    }

    /// Should connection `conn` corrupt the frame it is about to send?
    pub fn frame_corrupt(&self, conn: usize) -> bool {
        self.probe(FaultPoint::FrameCorrupt, conn).is_some()
    }

    /// Should sort-server process `node` die now? Probed at request
    /// admission; a `true` here is followed by an abrupt process exit
    /// (no drain, no goodbye — modelling a crash).
    pub fn node_down(&self, node: usize) -> bool {
        self.probe(FaultPoint::NodeDown, node).is_some()
    }

    /// Injected-fault totals per point name, for the metrics snapshot.
    pub fn injected(&self) -> BTreeMap<&'static str, u64> {
        let st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        st.injected.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(text: &str) -> FaultPlan {
        FaultPlan::parse(text).expect("valid plan")
    }

    #[test]
    fn parses_full_plan_and_roundtrips() {
        let p = plan(
            r#"{"version":1,"seed":42,"rules":[
                {"point":"device_lost","target":3},
                {"point":"slow_device","delay_ms":5,"probability":0.5,
                 "after":2,"count":7}
            ]}"#,
        );
        assert_eq!(p.version, 1);
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].point, FaultPoint::DeviceLost);
        assert_eq!(p.rules[0].target, Some(3));
        assert_eq!(p.rules[0].count, 1);
        assert_eq!(p.rules[0].probability, 1.0);
        assert_eq!(p.rules[1].after, 2);
        assert_eq!(p.rules[1].count, 7);
        assert_eq!(p.rules[1].delay_ms, 5);
        let round = FaultPlan::parse(&p.to_json().to_string_pretty()).unwrap();
        assert_eq!(round, p);
    }

    #[test]
    fn rejects_bad_plans() {
        for (text, needle) in [
            (r#"{"seed":1,"rules":[]}"#, "version"),
            (r#"{"version":2,"rules":[]}"#, "unsupported version"),
            (r#"{"version":1}"#, "rules"),
            (
                r#"{"version":1,"rules":[{"point":"meteor_strike"}]}"#,
                "unknown fault point",
            ),
            (
                r#"{"version":1,"rules":[{"point":"device_lost","count":0}]}"#,
                "count",
            ),
            (
                r#"{"version":1,"rules":[{"point":"device_lost","probability":1.5}]}"#,
                "probability",
            ),
            (
                r#"{"version":1,"rules":[{"point":"slow_device"}]}"#,
                "delay_ms",
            ),
        ] {
            let err = FaultPlan::parse(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text} -> {err}");
        }
    }

    #[test]
    fn resolve_empty_is_none() {
        assert!(FaultPlan::resolve("").unwrap().is_none());
        assert!(FaultPlan::resolve("/nonexistent/plan.json").is_err());
    }

    #[test]
    fn after_count_and_target_gate_firing() {
        let inj = plan(
            r#"{"version":1,"rules":[
                {"point":"device_lost","target":1,"after":1,"count":2}
            ]}"#,
        )
        .injector();
        // Wrong target: never eligible.
        assert_eq!(inj.device_fault(0), None);
        // Hit 1 on target 1: skipped by `after`.
        assert_eq!(inj.device_fault(1), None);
        // Hits 2 and 3: fire (count 2).
        assert_eq!(inj.device_fault(1), Some(DeviceFault::Lost));
        assert_eq!(inj.device_fault(1), Some(DeviceFault::Lost));
        // Exhausted.
        assert_eq!(inj.device_fault(1), None);
        assert_eq!(inj.injected().get("device_lost"), Some(&2));
    }

    #[test]
    fn oom_and_lost_precedence() {
        let inj = plan(
            r#"{"version":1,"rules":[
                {"point":"device_oom"},
                {"point":"device_lost"}
            ]}"#,
        )
        .injector();
        // Lost is probed first even though OOM is listed first.
        assert_eq!(inj.device_fault(5), Some(DeviceFault::Lost));
        assert_eq!(inj.device_fault(5), Some(DeviceFault::Oom));
        assert_eq!(inj.device_fault(5), None);
    }

    #[test]
    fn probabilistic_rules_replay_with_same_seed() {
        let text = r#"{"version":1,"seed":99,"rules":[
            {"point":"worker_panic","probability":0.5,"count":1000000}
        ]}"#;
        let a = plan(text).injector();
        let b = plan(text).injector();
        let fire_a: Vec<bool> = (0..64).map(|_| a.worker_panic(0)).collect();
        let fire_b: Vec<bool> = (0..64).map(|_| b.worker_panic(0)).collect();
        assert_eq!(fire_a, fire_b);
        assert!(fire_a.iter().any(|&f| f), "0.5 never fired in 64 draws");
        assert!(!fire_a.iter().all(|&f| f), "0.5 always fired in 64 draws");
    }

    #[test]
    fn point_probes_are_independent() {
        let inj = plan(
            r#"{"version":1,"rules":[
                {"point":"socket_cut","target":0},
                {"point":"frame_corrupt","target":1},
                {"point":"slow_device","delay_ms":7}
            ]}"#,
        )
        .injector();
        assert!(!inj.socket_cut(1));
        assert!(inj.socket_cut(0));
        assert!(!inj.frame_corrupt(0));
        assert!(inj.frame_corrupt(1));
        assert_eq!(inj.slow_device_ms(4), Some(7));
        assert_eq!(inj.slow_device_ms(4), None);
        let totals = inj.injected();
        assert_eq!(totals.get("socket_cut"), Some(&1));
        assert_eq!(totals.get("frame_corrupt"), Some(&1));
        assert_eq!(totals.get("slow_device"), Some(&1));
        assert_eq!(totals.get("device_lost"), None);
    }

    #[test]
    fn node_down_probe_fires_once_per_count() {
        let inj = plan(
            r#"{"version":1,"rules":[
                {"point":"node_down","target":0,"after":2}
            ]}"#,
        )
        .injector();
        // Wrong node index: never eligible.
        assert!(!inj.node_down(1));
        // Hits 1 and 2 skipped by `after`, hit 3 fires, then exhausted.
        assert!(!inj.node_down(0));
        assert!(!inj.node_down(0));
        assert!(inj.node_down(0));
        assert!(!inj.node_down(0));
        assert_eq!(inj.injected().get("node_down"), Some(&1));
    }

    #[test]
    fn loads_from_file() {
        let dir = std::env::temp_dir().join("gbs_fault_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        std::fs::write(
            &path,
            r#"{"version":1,"seed":7,"rules":[{"point":"device_lost"}]}"#,
        )
        .unwrap();
        let p = FaultPlan::resolve(path.to_str().unwrap()).unwrap().unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 1);
        std::fs::remove_file(&path).ok();
    }
}

//! Sharded engine scaling (beyond the paper): throughput vs device
//! count. The analytic table shows the memory ceiling moving out as
//! devices are added (one GTX 285 dies at 256M; pools of 2/4/8 reach
//! 512M and beyond) and the makespan speedup at fixed n; the executed
//! runs wall-clock the host engine and pin the executed ledger to the
//! analytic one.

mod common;

use gpu_bucket_sort::algos::sharded::{ShardedSort, ShardedSortParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{DevicePool, GpuModel};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale scaling table (1M – 512M × 1/2/4/8 GTX 285s).
    common::emit_table(&exp::sharded_scaling(
        &exp::paper_n_ladder(512 << 20),
        &[1, 2, 4, 8],
        GpuModel::Gtx285_2G,
    ));

    // (b) The heterogeneous default pool at 768M — past every single
    // device of Table 1 (the Tesla tops out at 512M).
    let sorter = ShardedSort::new(ShardedSortParams::default());
    let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
    let report = sorter.sort_analytic(768 << 20, &mut pool).unwrap();
    println!(
        "heterogeneous 4-device pool, n=768M: estimated makespan {:.1} ms ({:.1} Mkeys/s), shards {:?}",
        report.makespan_ms(&pool),
        report.sort_rate_mkeys_s(&pool),
        report.shard_sizes
    );

    // (c) Executed runs at a host-feasible size; executed and analytic
    // ledgers must agree device by device.
    let n = 1 << 21;
    let keys = Distribution::Uniform.generate(n, 9);
    let bencher = Bencher::from_env();
    let mut results = Vec::new();
    for count in [1usize, 2, 4] {
        let models = vec![GpuModel::Gtx285_2G; count];
        let mut makespan = 0.0;
        let r = bencher.bench(format!("sharded/exec/devices={count}"), || {
            let mut k = keys.clone();
            let mut pool = DevicePool::new(&models).unwrap();
            let report = sorter.sort(&mut k, &mut pool).unwrap();
            makespan = report.makespan_ms(&pool);
            k
        });
        let mut pool_e = DevicePool::new(&models).unwrap();
        let mut k = keys.clone();
        sorter.sort(&mut k, &mut pool_e).unwrap();
        let mut pool_a = DevicePool::new(&models).unwrap();
        sorter.sort_analytic(n, &mut pool_a).unwrap();
        for (d, (se, sa)) in pool_e.sims().iter().zip(pool_a.sims()).enumerate() {
            assert_eq!(
                se.ledger(),
                sa.ledger(),
                "executed != analytic ledger on device {d} of {count}"
            );
        }
        println!("    {count} device(s): simulated makespan {makespan:.2} ms");
        results.push(r);
    }
    common::emit_measurements("sharded", &results);
}

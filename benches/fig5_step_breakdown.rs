//! Figure 5: per-step runtime of Algorithm 1 on the GTX 285 — Steps 2
//! and 9 dominate, the deterministic-sampling overhead (Steps 3–7) is
//! small, and the relocation (Step 8) is nearly free.

mod common;

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale breakdown across the full n range.
    common::emit_table(&exp::fig5_step_breakdown(&exp::paper_n_ladder(256 << 20)));

    // (b) Executed breakdown at n = 1M, with the host-side wall time of
    // the full run.
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 5);
    let sorter = BucketSort::new(BucketSortParams::default());
    let bencher = Bencher::from_env();

    let mut k = keys.clone();
    let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
    let report = sorter.sort(&mut k, &mut sim).unwrap();
    println!("executed per-step estimates at n = {n}:");
    let steps = report.step_ms(sim.spec());
    let total: f64 = steps.values().sum();
    for (step, ms) in &steps {
        println!("    step {step}: {ms:8.3} ms ({:4.1}%)", 100.0 * ms / total);
    }

    let r = bencher.bench("fig5/exec/full", || {
        let mut k = keys.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort(&mut k, &mut sim).unwrap();
        k
    });
    common::emit_measurements("fig5", &[r]);
}

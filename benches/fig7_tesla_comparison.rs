//! Figure 7: the Figure-6 comparison on the Tesla C1060 — same
//! ordering, with GPU Bucket Sort alone reaching 512M keys (vs 128M for
//! the randomized method and 16M for Thrust Merge), plus the §5
//! sorting-rate series the figure's linearity implies.

mod common;

use gpu_bucket_sort::algos::Algorithm;
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale table (to 512M) + rate series.
    common::emit_table(&exp::fig7_tesla(&exp::paper_n_ladder(512 << 20)));
    common::emit_table(&exp::sort_rate_series(
        &exp::paper_n_ladder(512 << 20),
        GpuModel::TeslaC1060,
    ));

    // (b) Executed head-to-head at n = 1M on the simulated Tesla.
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 8);
    let bencher = Bencher::from_env();
    let mut results = Vec::new();
    for algo in Algorithm::ALL {
        let mut est = 0.0;
        let r = bencher.bench(format!("fig7/exec/{algo}"), || {
            let mut k = keys.clone();
            let mut sim = GpuSim::new(GpuModel::TeslaC1060.spec());
            est = algo.run(&mut k, &mut sim).unwrap();
            k
        });
        println!("    {algo}: simulated estimate {est:.2} ms");
        results.push(r);
    }
    common::emit_measurements("fig7", &results);
}

//! Execution-planner benchmarks — the PR-5 perf gates:
//!
//! * **fused vs unfused kernel**: the planner-scheduled wide-digit
//!   ping-pong LSD sort (`plan::planned_sort`, default 11-bit digits →
//!   3 passes over u32) against the PR-4 byte-wise kernel
//!   (`radix::radix_tile_sort`, 4 passes) on 16M uniform u32 keys —
//!   the CI gate requires ≥ 1.1×;
//! * **skip-pass planning**: the same comparison on low-entropy keys,
//!   where the occupancy sketch elides constant digits;
//! * **coalesced vs per-request dispatch**: one native engine with
//!   segment-tagged coalescing against one without, on a batch of
//!   256 × 64K-key requests (the many-small-users serving shape) — the
//!   CI gate requires ≥ 1.5×;
//! * byte-equality smokes for both comparisons.
//!
//! Emits `BENCH_planner.json` at the repo root — the perf-trajectory
//! record the CI bench-smoke job validates, gates on and uploads —
//! plus the usual `results/planner_wallclock.csv`.

mod common;

use gpu_bucket_sort::algos::{plan, radix};
use gpu_bucket_sort::config::{BatchConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{JobData, NativeSortEngine, SortEngine};
use gpu_bucket_sort::util::bench::{BenchResult, Bencher};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;

/// The kernel-gate size: 16M uniform u32 keys.
const GATE_N: usize = 1 << 24;

/// The dispatch-gate shape: 256 requests × 64K keys.
const BATCH_REQUESTS: usize = 256;
const BATCH_REQUEST_KEYS: usize = 64 << 10;

fn debiased_ms(r: &BenchResult, baseline_ms: f64) -> f64 {
    (r.median_ms() - baseline_ms).max(1e-3)
}

fn mkeys_s(n: usize, ms: f64) -> f64 {
    n as f64 / ms / 1e3
}

/// Byte-equality smoke: planned (several digit widths) and byte-wise
/// kernels must agree with the comparison sort on mixed-entropy u32
/// and on f32 with NaNs (compared on bits).
fn kernels_agree() -> bool {
    let mut u32_input = Distribution::Uniform.generate(100_000, 11);
    for (i, k) in u32_input.iter_mut().enumerate().take(30_000) {
        *k = (i % 127) as u32; // low-entropy stretch → skip-pass path
    }
    let mut expect = u32_input.clone();
    expect.sort_unstable();
    for bits in [8u32, 11, 13] {
        let mut keys = u32_input.clone();
        let (mut scratch, mut counts) = (Vec::new(), Vec::new());
        plan::planned_sort(&mut keys, &mut scratch, &mut counts, bits, None);
        if keys != expect {
            return false;
        }
    }
    let mut bytewise = u32_input.clone();
    let mut scratch = Vec::new();
    radix::radix_tile_sort(&mut bytewise, &mut scratch);
    if bytewise != expect {
        return false;
    }

    let mut f32_input: Vec<f32> = u32_input
        .iter()
        .map(|&b| <f32 as gpu_bucket_sort::SortKey>::from_raw_bits(b as u64))
        .collect();
    f32_input[3] = f32::NAN;
    f32_input[5] = -0.0;
    f32_input[7] = 0.0;
    let mut expect = f32_input.clone();
    expect.sort_unstable_by(gpu_bucket_sort::SortKey::key_cmp);
    let mut keys = f32_input;
    let (mut fscratch, mut counts) = (Vec::new(), Vec::new());
    plan::planned_sort(&mut keys, &mut fscratch, &mut counts, 11, None);
    keys.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
        == expect.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
}

/// The serving batch: `BATCH_REQUESTS` independent small requests.
fn small_request_batch() -> Vec<JobData> {
    (0..BATCH_REQUESTS as u64)
        .map(|i| JobData::new(Distribution::Uniform.generate(BATCH_REQUEST_KEYS, i)))
        .collect()
}

fn main() {
    let bencher = Bencher::from_env();
    let fast = std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1");
    let digit_bits = plan::DEFAULT_DIGIT_BITS;
    let mut results = Vec::new();

    // --- fused (planned wide-digit) vs unfused (byte-wise) kernel ----
    let keys16 = Distribution::Uniform.generate(GATE_N, 1);
    let clone_r = bencher.bench("planner/clone_only/n=16M", || keys16.clone());
    let clone_ms = clone_r.median_ms();

    let (mut scratch, mut counts) = (Vec::new(), Vec::new());
    let planned_r = bencher.bench(format!("planner/planned_d{digit_bits}/n=16M"), || {
        let mut k = keys16.clone();
        plan::planned_sort(&mut k, &mut scratch, &mut counts, digit_bits, None);
        k
    });
    let mut byte_scratch = Vec::new();
    let bytewise_r = bencher.bench("planner/bytewise_d8/n=16M", || {
        let mut k = keys16.clone();
        radix::radix_tile_sort(&mut k, &mut byte_scratch);
        k
    });
    let planned_ms = debiased_ms(&planned_r, clone_ms);
    let bytewise_ms = debiased_ms(&bytewise_r, clone_ms);
    let kernel_speedup = bytewise_ms / planned_ms;
    let plan16 = plan::plan_for(&keys16, digit_bits);
    println!(
        "    16M uniform u32 (clone-debiased): planned {:.1} Mkeys/s ({} passes) | \
         byte-wise {:.1} Mkeys/s (4 passes) | {kernel_speedup:.2}x",
        mkeys_s(GATE_N, planned_ms),
        plan16.passes.len(),
        mkeys_s(GATE_N, bytewise_ms),
    );
    results.push(clone_r);
    results.push(planned_r);
    results.push(bytewise_r);

    // --- skip-pass planning on low-entropy keys ----------------------
    let low_n = if fast { 1 << 22 } else { GATE_N };
    let low_keys: Vec<u32> = Distribution::Uniform
        .generate(low_n, 2)
        .into_iter()
        .map(|x| x & 0xFFFF)
        .collect();
    let low_clone_r = bencher.bench("planner/low_clone/n=low", || low_keys.clone());
    let low_clone_ms = low_clone_r.median_ms();
    let low_planned_r = bencher.bench(format!("planner/planned_low_d{digit_bits}"), || {
        let mut k = low_keys.clone();
        plan::planned_sort(&mut k, &mut scratch, &mut counts, digit_bits, None);
        k
    });
    let low_bytewise_r = bencher.bench("planner/bytewise_low_d8", || {
        let mut k = low_keys.clone();
        radix::radix_tile_sort(&mut k, &mut byte_scratch);
        k
    });
    let low_plan = plan::plan_for(&low_keys, digit_bits);
    let low_speedup = debiased_ms(&low_bytewise_r, low_clone_ms)
        / debiased_ms(&low_planned_r, low_clone_ms);
    println!(
        "    16-bit-entropy keys: planner schedules {} of {} passes ({} skipped) — \
         {low_speedup:.2}x over byte-wise",
        low_plan.passes.len(),
        low_plan.nominal_passes,
        low_plan.skipped(),
    );
    results.push(low_clone_r);
    results.push(low_planned_r);
    results.push(low_bytewise_r);

    // --- coalesced vs per-request dispatch ---------------------------
    let batch = small_request_batch();
    let batch_keys = BATCH_REQUESTS * BATCH_REQUEST_KEYS;
    let batch_clone_r = bencher.bench("planner/batch_clone/256x64K", || batch.clone());
    let batch_clone_ms = batch_clone_r.median_ms();

    let coalesced_cfg = ServiceConfig::default();
    assert!(
        coalesced_cfg.batch.coalesce_max_keys >= BATCH_REQUEST_KEYS,
        "default coalesce cap must admit the gate's request size"
    );
    let per_request_cfg = ServiceConfig {
        batch: BatchConfig {
            coalesce_max_keys: 0,
            ..Default::default()
        },
        ..Default::default()
    };

    let mut coalesced_engine = NativeSortEngine::new(&coalesced_cfg).unwrap();
    let mut per_request_engine = NativeSortEngine::new(&per_request_cfg).unwrap();
    // Warm both arenas once, untimed.
    coalesced_engine.sort_batch(batch.clone());
    per_request_engine.sort_batch(batch.clone());

    let coalesced_r = bencher.bench("planner/dispatch_coalesced/256x64K", || {
        coalesced_engine.sort_batch(batch.clone())
    });
    let per_request_r = bencher.bench("planner/dispatch_per_request/256x64K", || {
        per_request_engine.sort_batch(batch.clone())
    });
    let coalesced_ms = debiased_ms(&coalesced_r, batch_clone_ms);
    let per_request_ms = debiased_ms(&per_request_r, batch_clone_ms);
    let dispatch_speedup = per_request_ms / coalesced_ms;
    println!(
        "    {BATCH_REQUESTS}×{BATCH_REQUEST_KEYS} keys (clone-debiased): coalesced \
         {:.1} Mkeys/s | per-request {:.1} Mkeys/s | {dispatch_speedup:.2}x",
        mkeys_s(batch_keys, coalesced_ms),
        mkeys_s(batch_keys, per_request_ms),
    );
    results.push(batch_clone_r);
    results.push(coalesced_r);
    results.push(per_request_r);

    // Dispatch byte-equality: the coalesced responses must match the
    // per-request responses exactly, request by request.
    let coalesced_out = coalesced_engine.sort_batch(batch.clone());
    let per_request_out = per_request_engine.sort_batch(batch);
    let dispatch_agree = coalesced_out
        .iter()
        .zip(&per_request_out)
        .all(|(a, b)| match (a, b) {
            (Ok(a), Ok(b)) => a.keys == b.keys && a.payload == b.payload,
            _ => false,
        });
    println!("    coalesced responses byte-identical to per-request: {dispatch_agree}");

    let agree = kernels_agree();
    println!("    kernels agree byte-for-byte: {agree}");

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("median_ms", Json::num(r.median_ms())),
                ("mean_ms", Json::num(r.mean_ms())),
                ("min_ms", Json::num(r.min_ms())),
                ("samples", Json::num(r.samples_ms.len() as f64)),
            ])
        })
        .collect();
    let report = Json::obj(vec![
        ("bench", Json::str("planner")),
        ("mode", Json::str(if fast { "smoke" } else { "full" })),
        ("digit_bits", Json::num(digit_bits as f64)),
        ("gate_n", Json::num(GATE_N as f64)),
        ("clone_median_ms", Json::num(clone_ms)),
        ("planned_passes", Json::num(plan16.passes.len() as f64)),
        ("planned_mkeys_s", Json::num(mkeys_s(GATE_N, planned_ms))),
        ("bytewise_mkeys_s", Json::num(mkeys_s(GATE_N, bytewise_ms))),
        ("planned_vs_bytewise", Json::num(kernel_speedup)),
        (
            "low_entropy",
            Json::obj(vec![
                ("n", Json::num(low_n as f64)),
                ("planned_passes", Json::num(low_plan.passes.len() as f64)),
                ("nominal_passes", Json::num(low_plan.nominal_passes as f64)),
                ("skipped", Json::num(low_plan.skipped() as f64)),
                ("planned_vs_bytewise", Json::num(low_speedup)),
            ]),
        ),
        (
            "dispatch",
            Json::obj(vec![
                ("requests", Json::num(BATCH_REQUESTS as f64)),
                ("request_keys", Json::num(BATCH_REQUEST_KEYS as f64)),
                ("coalesced_mkeys_s", Json::num(mkeys_s(batch_keys, coalesced_ms))),
                (
                    "per_request_mkeys_s",
                    Json::num(mkeys_s(batch_keys, per_request_ms)),
                ),
                ("coalesced_vs_per_request", Json::num(dispatch_speedup)),
                ("responses_agree", Json::Bool(dispatch_agree)),
            ]),
        ),
        ("kernels_agree", Json::Bool(agree)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_planner.json", report.to_string_pretty())
        .expect("write BENCH_planner.json");
    println!("→ BENCH_planner.json");

    common::emit_measurements("planner", &results);

    if !agree || !dispatch_agree {
        eprintln!("FAIL: planner or coalescing outputs diverged");
        std::process::exit(1);
    }
}

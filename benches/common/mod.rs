//! Shared bench scaffolding: every `fig*` bench (a) regenerates its
//! paper table from the analytic model (the reproduction artifact), and
//! (b) wall-clock-measures the *executed* algorithm at host-feasible
//! sizes with the in-tree harness, verifying executed and analytic
//! ledgers agree where both exist.

use gpu_bucket_sort::experiments::ExpTable;
use gpu_bucket_sort::util::bench::BenchResult;
use std::path::Path;

/// Print + persist a regenerated paper table.
pub fn emit_table(table: &ExpTable) {
    println!("{}", table.to_markdown());
    match table.write_csv(Path::new("results")) {
        Ok(p) => println!("→ {}\n", p.display()),
        Err(e) => eprintln!("(csv write failed: {e})"),
    }
}

/// Persist wall-clock measurements alongside the table.
pub fn emit_measurements(name: &str, results: &[BenchResult]) {
    let path = Path::new("results").join(format!("{name}_wallclock.csv"));
    if let Err(e) = gpu_bucket_sort::util::bench::write_csv(&path, results) {
        eprintln!("(wallclock csv write failed: {e})");
    } else {
        println!("→ {}", path.display());
    }
}

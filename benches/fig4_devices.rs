//! Figure 4: GPU Bucket Sort runtime for varying n on the Tesla C1060,
//! GTX 260 and GTX 285 — near-linear growth, bandwidth-bound device
//! ordering, and the per-device memory ceilings.

mod common;

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale table (1M – 512M, ceilings included).
    common::emit_table(&exp::fig4_devices(&exp::paper_n_ladder(512 << 20)));

    // (b) Executed runs across devices at n = 1M: same ledger priced per
    // device; wall time measures the host execution engine.
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 4);
    let sorter = BucketSort::new(BucketSortParams::default());
    let bencher = Bencher::from_env();
    let mut results = Vec::new();
    for gpu in [GpuModel::TeslaC1060, GpuModel::Gtx260, GpuModel::Gtx285_2G] {
        let mut est = 0.0;
        let r = bencher.bench(format!("fig4/exec/{}", gpu.spec().name), || {
            let mut k = keys.clone();
            let mut sim = GpuSim::new(gpu.spec());
            let report = sorter.sort(&mut k, &mut sim).unwrap();
            est = report.total_estimated_ms(sim.spec());
            k
        });
        println!("    {}: simulated estimate {est:.2} ms", gpu.spec().name);
        results.push(r);
    }
    common::emit_measurements("fig4", &results);
}

//! Chaos resilience: throughput under degraded hardware and the cost
//! of end-to-end recovery.
//!
//! Three scenarios, all byte-identity-checked against a local
//! `sort_unstable` (violations are counted and gated at zero):
//!
//! * **healthy** — sharded service over the full 4-device pool;
//! * **degraded** — same load with a fault plan that kills one device
//!   on the first step, so every request runs failover re-planning
//!   over the 3 survivors. The headline gate (`ci/validate_bench.py`)
//!   requires `degraded_ratio ≥ 0.6` — losing a quarter of the pool
//!   may cost throughput, but never more than a bounded slice and
//!   never bytes;
//! * **recovery** — a TCP round-trip load where a seeded `socket_cut`
//!   severs the connection mid-run; the reconnecting client must ride
//!   through it (reconnect + idempotent resubmit), and the cut
//!   request's latency is reported as `recovered_request_ms` next to
//!   the healthy median.
//!
//! Emits `BENCH_chaos.json` at the repo root — validated by CI's
//! `chaos` job. `GBS_BENCH_FAST=1` selects the smoke profile.

use gpu_bucket_sort::config::{EngineKind, NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::net::{ClientOptions, NetClient, NetServer};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use std::time::Instant;

struct Profile {
    mode: &'static str,
    requests: usize,
    keys_per_request: usize,
}

impl Profile {
    fn from_env() -> Profile {
        if std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1") {
            Profile {
                mode: "smoke",
                requests: 16,
                keys_per_request: 50_000,
            }
        } else {
            Profile {
                mode: "full",
                requests: 32,
                keys_per_request: 200_000,
            }
        }
    }
}

/// Write a fault plan beside the bench artifacts; returns its path.
fn write_plan(name: &str, json: &str) -> String {
    let dir = std::env::temp_dir().join(format!("gbs_chaos_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let p = dir.join(format!("{name}.json"));
    std::fs::write(&p, json).expect("write plan");
    p.display().to_string()
}

struct LoadResult {
    wall_ms: f64,
    mkeys_s: f64,
    latencies_ms: Vec<f64>,
    violations: u64,
}

/// Sequential in-process load against a service; byte-identity checked
/// per request.
fn run_service_load(cfg: ServiceConfig, profile: &Profile, seed: u64) -> LoadResult {
    let service = SortService::start(cfg).expect("service starts");
    let n = profile.keys_per_request;
    let mut latencies_ms = Vec::with_capacity(profile.requests);
    let mut violations = 0u64;
    let t0 = Instant::now();
    for r in 0..profile.requests {
        let keys = Distribution::Uniform.generate(n, seed * 10_000 + r as u64 + 1);
        let mut expected = keys.clone();
        expected.sort_unstable();
        let t = Instant::now();
        let out = service.sort(SortRequest::new(keys)).expect("sort succeeds");
        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
        if out.keys_u32() != expected.as_slice() {
            violations += 1;
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _ = service.shutdown();
    LoadResult {
        wall_ms,
        mkeys_s: (profile.requests * n) as f64 / wall_ms * 1e3 / 1e6,
        latencies_ms,
        violations,
    }
}

fn median(sorted: &[f64]) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[sorted.len() / 2]
}

fn main() {
    let profile = Profile::from_env();
    println!(
        "chaos_resilience [{}]: {} requests × {} u32 keys, sharded over 4 devices",
        profile.mode, profile.requests, profile.keys_per_request
    );

    // Scenario 1: healthy pool.
    let healthy_cfg = ServiceConfig {
        engine: EngineKind::Sharded,
        verify: false,
        ..ServiceConfig::default()
    };
    let healthy = run_service_load(healthy_cfg.clone(), &profile, 1);
    println!(
        "  healthy   {:>8.1} ms  {:>7.2} Mkeys/s",
        healthy.wall_ms, healthy.mkeys_s
    );

    // Scenario 2: one device lost on the first step — every request
    // thereafter re-plans over the 3 survivors.
    let degraded_plan = write_plan(
        "degraded",
        r#"{"version":1,"seed":1,"rules":[{"point":"device_lost","target":0,"count":1}]}"#,
    );
    let degraded_cfg = ServiceConfig {
        fault_plan: degraded_plan,
        ..healthy_cfg
    };
    let degraded = run_service_load(degraded_cfg, &profile, 2);
    let ratio = if healthy.mkeys_s > 0.0 {
        degraded.mkeys_s / healthy.mkeys_s
    } else {
        0.0
    };
    println!(
        "  degraded  {:>8.1} ms  {:>7.2} Mkeys/s  ({:.2}× healthy)",
        degraded.wall_ms, degraded.mkeys_s, ratio
    );

    // Scenario 3: TCP recovery — a seeded socket cut mid-run; the
    // reconnecting client rides through with identical bytes.
    let cut_at = profile.requests / 2;
    let recovery_plan = write_plan(
        "recovery",
        &format!(
            r#"{{"version":1,"seed":2,"rules":[{{"point":"socket_cut","target":0,"after":{cut_at},"count":1}}]}}"#
        ),
    );
    let service = SortService::start(ServiceConfig {
        fault_plan: recovery_plan,
        verify: false,
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let server =
        NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).expect("bind");
    let addr = server.local_addr().to_string();
    let client = NetClient::connect_with(
        &addr,
        1,
        NetConfig::default(),
        ClientOptions {
            reconnect: true,
            faults: service.fault_injector(),
        },
    )
    .expect("connect");
    let n = profile.keys_per_request;
    let mut violations = 0u64;
    let mut recovered_request_ms = 0.0f64;
    let mut net_latencies = Vec::with_capacity(profile.requests);
    for r in 0..profile.requests {
        let keys = Distribution::Uniform.generate(n, 77_000 + r as u64);
        let mut expected = keys.clone();
        expected.sort_unstable();
        let before = client.reconnects();
        let t = Instant::now();
        let out = client.sort(SortRequest::new(keys)).expect("sort succeeds");
        let ms = t.elapsed().as_secs_f64() * 1e3;
        if client.reconnects() > before {
            recovered_request_ms = ms;
        } else {
            net_latencies.push(ms);
        }
        if out.keys_u32() != expected.as_slice() {
            violations += 1;
        }
    }
    let reconnects = client.reconnects();
    let resubmits = client.resubmits();
    drop(client);
    let _ = server.shutdown();
    net_latencies.sort_by(f64::total_cmp);
    let median_healthy_ms = median(&net_latencies);
    println!(
        "  recovery  reconnects={reconnects} resubmits={resubmits}  \
         cut request {recovered_request_ms:.1} ms vs healthy median {median_healthy_ms:.1} ms"
    );

    let total_violations = healthy.violations + degraded.violations + violations;
    let mut h = healthy.latencies_ms.clone();
    h.sort_by(f64::total_cmp);
    let mut d = degraded.latencies_ms.clone();
    d.sort_by(f64::total_cmp);
    let report = Json::obj(vec![
        ("bench", Json::str("chaos_resilience")),
        ("schema_version", Json::num(1.0)),
        ("mode", Json::str(profile.mode)),
        ("engine", Json::str("sharded")),
        ("requests", Json::num(profile.requests as f64)),
        ("keys_per_request", Json::num(profile.keys_per_request as f64)),
        ("byte_identity_violations", Json::num(total_violations as f64)),
        ("healthy_mkeys_s", Json::num(healthy.mkeys_s)),
        ("degraded_mkeys_s", Json::num(degraded.mkeys_s)),
        ("degraded_ratio", Json::num(ratio)),
        (
            "recovery",
            Json::obj(vec![
                ("reconnects", Json::num(reconnects as f64)),
                ("resubmits", Json::num(resubmits as f64)),
                ("recovered_request_ms", Json::num(recovered_request_ms)),
                ("median_healthy_ms", Json::num(median_healthy_ms)),
            ]),
        ),
        (
            "results",
            Json::Arr(vec![
                Json::obj(vec![
                    ("scenario", Json::str("healthy")),
                    ("wall_ms", Json::num(healthy.wall_ms)),
                    ("mkeys_s", Json::num(healthy.mkeys_s)),
                    ("p50_ms", Json::num(median(&h))),
                ]),
                Json::obj(vec![
                    ("scenario", Json::str("degraded")),
                    ("wall_ms", Json::num(degraded.wall_ms)),
                    ("mkeys_s", Json::num(degraded.mkeys_s)),
                    ("p50_ms", Json::num(median(&d))),
                ]),
            ]),
        ),
    ]);
    std::fs::write("BENCH_chaos.json", report.to_string_pretty()).expect("write BENCH_chaos.json");
    println!("→ BENCH_chaos.json");

    // In-bench gates (CI re-checks them from the JSON): bytes are
    // sacred, and the cut must actually have been exercised.
    assert_eq!(total_violations, 0, "byte identity violated under chaos");
    assert!(reconnects >= 1, "the socket cut never fired");
    assert!(resubmits >= 1, "the cut request was never resubmitted");
    println!("gate OK: 0 byte-identity violations, recovery exercised");
}

//! Cluster failover: throughput and recovery latency of the
//! registry-backed multi-node tier, as real processes.
//!
//! Harness: one `gbs registry` process, three `gbs serve --registry`
//! node processes, and M in-process client threads, each driving its
//! own [`ClusterClient`] (registry-resolved routing, cross-node
//! failover). Two scenarios:
//!
//! * **healthy** — all three nodes stay up for the whole load;
//! * **one node killed** — once roughly a third of the load has
//!   completed, the parent SIGKILLs the node the clients are routed
//!   to. Every in-flight request must fail over to a survivor: the
//!   gate is **zero** failed client requests, **zero** byte-identity
//!   violations (each response is checked against a local
//!   `sort_unstable` — the same bytes a single-node run produces,
//!   because sorting is deterministic), and degraded throughput no
//!   worse than half of healthy. The latency of each request that rode
//!   a failover is reported next to the healthy median.
//!
//! Emits `BENCH_cluster.json` at the repo root — validated by CI's
//! chaos job via `ci/validate_bench.py cluster`. `GBS_BENCH_FAST=1`
//! selects the smoke profile.

use gpu_bucket_sort::config::NetConfig;
use gpu_bucket_sort::coordinator::SortRequest;
use gpu_bucket_sort::net::{ClusterClient, ClusterOptions};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

const NODES: usize = 3;

struct Profile {
    mode: &'static str,
    clients: usize,
    requests_per_client: usize,
    keys_per_request: usize,
}

impl Profile {
    fn from_env() -> Profile {
        if std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1") {
            Profile {
                mode: "smoke",
                clients: 2,
                requests_per_client: 8,
                keys_per_request: 40_000,
            }
        } else {
            Profile {
                mode: "full",
                clients: 4,
                requests_per_client: 16,
                keys_per_request: 150_000,
            }
        }
    }

    fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }
}

/// A spawned `gbs` child whose stdout pipe stays open (dropping it
/// would EPIPE the child's later prints).
struct Proc {
    child: Child,
    _out: BufReader<ChildStdout>,
}

impl Proc {
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `gbs` and scrape its machine-readable address line.
fn spawn_gbs(args: &[&str], scrape_prefix: &str) -> (Proc, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_gbs"))
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn gbs");
    let mut out = BufReader::new(child.stdout.take().expect("child stdout piped"));
    let mut line = String::new();
    loop {
        line.clear();
        if out.read_line(&mut line).expect("read child stdout") == 0 {
            panic!("gbs {args:?} exited before announcing {scrape_prefix}");
        }
        if let Some(rest) = line.strip_prefix(scrape_prefix) {
            return (Proc { child, _out: out }, rest.trim().to_string());
        }
    }
}

/// Registry + `NODES` node processes; returns (registry, nodes keyed
/// by advertised address, registry address).
fn spawn_cluster() -> (Proc, Vec<(Proc, String)>, String) {
    let (registry, reg_addr) = spawn_gbs(
        &["registry", "--listen", "127.0.0.1:0", "--heartbeat-ms", "50"],
        "GBS_REGISTRY_ADDR ",
    );
    let nodes: Vec<(Proc, String)> = (0..NODES)
        .map(|_| {
            spawn_gbs(
                &[
                    "serve", "--listen", "127.0.0.1:0", "--registry", &reg_addr,
                    "--workers", "2",
                ],
                "GBS_NET_ADDR ",
            )
        })
        .collect();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let listed = gpu_bucket_sort::net::registry::node_list(&reg_addr)
            .map(|v| v.len())
            .unwrap_or(0);
        if listed == NODES {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "registry never listed all {NODES} nodes (currently {listed})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    (registry, nodes, reg_addr)
}

#[derive(Default)]
struct ClientResult {
    latencies_ms: Vec<f64>,
    failover_latencies_ms: Vec<f64>,
    violations: u64,
    failed: u64,
    failovers: u64,
}

/// One client thread: sequential byte-identity-checked sorts through
/// its own cluster client. Requests that rode a failover report their
/// latency separately.
fn run_client(
    reg_addr: &str,
    seed0: u64,
    requests: usize,
    n: usize,
    completed: &AtomicU64,
) -> ClientResult {
    let mut out = ClientResult::default();
    let cluster = match ClusterClient::connect(reg_addr, NetConfig::default(), ClusterOptions::default())
    {
        Ok(c) => c,
        Err(_) => {
            out.failed = requests as u64;
            // Still count toward progress so the kill choreography in
            // the parent never waits on requests that will not happen.
            completed.fetch_add(requests as u64, Ordering::Relaxed);
            return out;
        }
    };
    for r in 0..requests {
        let keys = Distribution::Uniform.generate(n, seed0 * 10_000 + r as u64 + 1);
        let mut expected = keys.clone();
        expected.sort_unstable();
        let before = cluster.failovers();
        let t = Instant::now();
        match cluster.sort(SortRequest::new(keys)) {
            Ok(resp) => {
                let ms = t.elapsed().as_secs_f64() * 1e3;
                if cluster.failovers() > before {
                    out.failover_latencies_ms.push(ms);
                } else {
                    out.latencies_ms.push(ms);
                }
                if resp.keys_u32() != expected.as_slice() {
                    out.violations += 1;
                }
            }
            Err(_) => out.failed += 1,
        }
        completed.fetch_add(1, Ordering::Relaxed);
    }
    out.failovers = cluster.failovers();
    out
}

struct Scenario {
    wall_ms: f64,
    mkeys_s: f64,
    merged: ClientResult,
}

/// Drive the full client load against a fresh cluster. When
/// `kill_one_node` is set, the routed node is SIGKILLed after roughly
/// a third of the total requests have completed.
fn run_scenario(profile: &Profile, kill_one_node: bool) -> Scenario {
    let (registry, mut nodes, reg_addr) = spawn_cluster();
    let completed = AtomicU64::new(0);
    let kill_after = (profile.total_requests() / 3).max(1) as u64;

    let t0 = Instant::now();
    let merged = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..profile.clients)
            .map(|c| {
                let reg_addr = reg_addr.clone();
                let completed = &completed;
                scope.spawn(move || {
                    run_client(
                        &reg_addr,
                        c as u64 + 1,
                        profile.requests_per_client,
                        profile.keys_per_request,
                        completed,
                    )
                })
            })
            .collect();
        if kill_one_node {
            // With equal advertised loads every client routes to the
            // first node in address order — that is the one to kill.
            let mut routed: Vec<String> = nodes.iter().map(|(_, a)| a.clone()).collect();
            routed.sort();
            let victim_addr = routed[0].clone();
            while completed.load(Ordering::Relaxed) < kill_after {
                std::thread::sleep(Duration::from_millis(5));
            }
            let pos = nodes
                .iter()
                .position(|(_, a)| *a == victim_addr)
                .expect("victim among spawned nodes");
            let (victim, _) = nodes.swap_remove(pos);
            victim.kill(); // SIGKILL mid-load — no drain, no deregister
        }
        let mut merged = ClientResult::default();
        for h in handles {
            let r = h.join().expect("client thread");
            merged.latencies_ms.extend(r.latencies_ms);
            merged.failover_latencies_ms.extend(r.failover_latencies_ms);
            merged.violations += r.violations;
            merged.failed += r.failed;
            merged.failovers += r.failovers;
        }
        merged
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    for (node, _) in nodes {
        node.kill();
    }
    registry.kill();

    let keys_done =
        (profile.total_requests() as u64 - merged.failed) * profile.keys_per_request as u64;
    Scenario {
        wall_ms,
        mkeys_s: keys_done as f64 / wall_ms * 1e3 / 1e6,
        merged,
    }
}

fn median(sorted: &[f64]) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[sorted.len() / 2]
}

fn max_of(values: &[f64]) -> f64 {
    values.iter().copied().fold(0.0, f64::max)
}

fn main() {
    let profile = Profile::from_env();
    println!(
        "cluster_failover [{}]: registry + {NODES} nodes, {} clients × {} requests × {} u32 keys",
        profile.mode, profile.clients, profile.requests_per_client, profile.keys_per_request
    );

    let healthy = run_scenario(&profile, false);
    println!(
        "  healthy      {:>8.1} ms  {:>7.2} Mkeys/s  (failed {}, violations {})",
        healthy.wall_ms, healthy.mkeys_s, healthy.merged.failed, healthy.merged.violations
    );

    let degraded = run_scenario(&profile, true);
    let ratio = if healthy.mkeys_s > 0.0 {
        degraded.mkeys_s / healthy.mkeys_s
    } else {
        0.0
    };
    let mut healthy_lat = healthy.merged.latencies_ms.clone();
    healthy_lat.sort_by(f64::total_cmp);
    let healthy_p50 = median(&healthy_lat);
    let max_failover_ms = max_of(&degraded.merged.failover_latencies_ms);
    println!(
        "  node killed  {:>8.1} ms  {:>7.2} Mkeys/s  ({ratio:.2}× healthy, failed {}, \
         violations {}, {} failover(s), worst failover {max_failover_ms:.1} ms vs \
         healthy p50 {healthy_p50:.1} ms)",
        degraded.wall_ms, degraded.mkeys_s, degraded.merged.failed, degraded.merged.violations,
        degraded.merged.failovers
    );

    let violations = healthy.merged.violations + degraded.merged.violations;
    let failed = healthy.merged.failed + degraded.merged.failed;
    let mut degraded_lat = degraded.merged.latencies_ms.clone();
    degraded_lat.sort_by(f64::total_cmp);
    let report = Json::obj(vec![
        ("bench", Json::str("cluster_failover")),
        ("schema_version", Json::num(1.0)),
        ("mode", Json::str(profile.mode)),
        ("nodes", Json::num(NODES as f64)),
        ("clients", Json::num(profile.clients as f64)),
        ("requests", Json::num(profile.total_requests() as f64)),
        ("keys_per_request", Json::num(profile.keys_per_request as f64)),
        ("byte_identity_violations", Json::num(violations as f64)),
        ("failed_requests", Json::num(failed as f64)),
        ("healthy_mkeys_s", Json::num(healthy.mkeys_s)),
        ("degraded_mkeys_s", Json::num(degraded.mkeys_s)),
        ("degraded_ratio", Json::num(ratio)),
        (
            "failover",
            Json::obj(vec![
                ("failovers", Json::num(degraded.merged.failovers as f64)),
                ("max_failover_ms", Json::num(max_failover_ms)),
                ("healthy_p50_ms", Json::num(healthy_p50)),
            ]),
        ),
        (
            "results",
            Json::Arr(vec![
                Json::obj(vec![
                    ("scenario", Json::str("healthy")),
                    ("wall_ms", Json::num(healthy.wall_ms)),
                    ("mkeys_s", Json::num(healthy.mkeys_s)),
                    ("p50_ms", Json::num(healthy_p50)),
                ]),
                Json::obj(vec![
                    ("scenario", Json::str("one_node_killed")),
                    ("wall_ms", Json::num(degraded.wall_ms)),
                    ("mkeys_s", Json::num(degraded.mkeys_s)),
                    ("p50_ms", Json::num(median(&degraded_lat))),
                ]),
            ]),
        ),
    ]);
    std::fs::write("BENCH_cluster.json", report.to_string_pretty())
        .expect("write BENCH_cluster.json");
    println!("→ BENCH_cluster.json");

    // In-bench gates (CI re-checks them from the JSON): no request may
    // fail, no byte may differ, and the kill must actually have been
    // ridden through.
    assert_eq!(violations, 0, "byte identity violated across the cluster");
    assert_eq!(failed, 0, "a client request failed despite failover");
    assert!(
        degraded.merged.failovers >= 1,
        "the killed node was never routed to — the scenario proved nothing"
    );
    assert!(
        ratio >= 0.5,
        "losing 1 of {NODES} nodes cost more than half the throughput ({ratio:.2}x)"
    );
    println!("gate OK: 0 failed requests, 0 byte-identity violations, failover exercised");
}

//! Hot-path wall-clock benchmarks — the §Perf working set, now a CI
//! perf gate:
//!
//! * native engine vs `slice::sort_unstable` at 16M uniform keys, with
//!   a clone-only baseline so throughput can be reported **de-biased**
//!   (the input clone inside the timed closure is subtracted out);
//! * the pre-PR native configuration (comparison kernel, cold arena
//!   every call) vs the arena'd radix default;
//! * radix vs bitonic tile kernel (Step 2's inner loop) plus an
//!   output-equality smoke across kernels;
//! * arena-on vs arena-off through the executed Algorithm 1;
//! * service round trip (batching + scheduler overhead).
//!
//! Emits `BENCH_hot_paths.json` at the repo root — the perf-trajectory
//! record the CI bench-smoke job validates and gates on — plus the
//! usual `results/hot_paths_wallclock.csv`.

mod common;

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::algos::{bitonic, radix};
use gpu_bucket_sort::config::ServiceConfig;
use gpu_bucket_sort::coordinator::SortService;
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::{BenchResult, Bencher};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{ExecContext, KernelKind, SortKey};

/// The CI gate size: 16M uniform u32 keys.
const GATE_N: usize = 1 << 24;

/// Median milliseconds with the clone baseline subtracted (floored at a
/// microsecond so a ratio never divides by zero).
fn debiased_ms(r: &BenchResult, clone_ms: f64) -> f64 {
    (r.median_ms() - clone_ms).max(1e-3)
}

fn mkeys_s(n: usize, ms: f64) -> f64 {
    n as f64 / ms / 1e3
}

/// Output-equality smoke: both kernels must produce byte-identical
/// results through the executed Algorithm 1 and the native engine, for
/// u32 and for f32 with NaNs/−0.0 (compared on bits).
fn kernels_agree() -> bool {
    let sorter = BucketSort::new(BucketSortParams { tile: 256, s: 16 });
    let u32_input = Distribution::Uniform.generate(40_000, 7);
    let mut f32_input: Vec<f32> = u32_input
        .iter()
        .map(|&b| <f32 as SortKey>::from_raw_bits(b as u64))
        .collect();
    f32_input[11] = f32::NAN;
    f32_input[12] = -0.0;
    f32_input[13] = 0.0;

    let run_u32 = |kernel: KernelKind| {
        let mut keys = u32_input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter
            .sort_in(&mut keys, &mut sim, &ExecContext::new(kernel, 0))
            .expect("bucket sort");
        keys
    };
    let run_f32 = |kernel: KernelKind| {
        let mut keys = f32_input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter
            .sort_in(&mut keys, &mut sim, &ExecContext::new(kernel, 0))
            .expect("bucket sort");
        keys.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
    };
    let run_native = |kernel: KernelKind| {
        let e = NativeEngine::with_context(
            NativeParams {
                sequential_cutoff: 1 << 10,
                ..Default::default()
            },
            ExecContext::new(kernel, 0),
        )
        .expect("native engine");
        let mut keys = u32_input.clone();
        let mut payload: Vec<u64> = (0..keys.len() as u64).collect();
        e.sort_pairs(&mut keys, &mut payload).expect("sort_pairs");
        (keys, payload)
    };

    run_u32(KernelKind::Radix) == run_u32(KernelKind::Bitonic)
        && run_f32(KernelKind::Radix) == run_f32(KernelKind::Bitonic)
        && run_native(KernelKind::Radix) == run_native(KernelKind::Bitonic)
}

fn main() {
    let bencher = Bencher::from_env();
    let fast = std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1");
    let mut results = Vec::new();

    // --- 16M-key gate: clone baseline, std sort, native old/new ------
    let keys16 = Distribution::Uniform.generate(GATE_N, 1);
    let clone_r = bencher.bench("hot/clone_only/n=16M", || keys16.clone());
    let clone_ms = clone_r.median_ms();

    let std_r = bencher.bench("hot/std_sort/n=16M", || {
        let mut k = keys16.clone();
        k.sort_unstable();
        k
    });

    // The default hot path: radix kernel, resident pool, arena warmed
    // by one untimed run.
    let engine = NativeEngine::new(NativeParams::default()).unwrap();
    println!("native engine: {} workers", engine.workers());
    {
        let mut warm = keys16.clone();
        engine.sort(&mut warm);
    }
    let native_r = bencher.bench("hot/native_radix_arena/n=16M", || {
        let mut k = keys16.clone();
        engine.sort(&mut k);
        k
    });

    // The pre-PR configuration: comparison kernel, and a fresh engine
    // (cold arena) every call — what every request used to pay.
    let legacy_r = bencher.bench("hot/native_bitonic_coldarena/n=16M", || {
        let e = NativeEngine::with_context(
            NativeParams::default(),
            ExecContext::new(KernelKind::Bitonic, 0),
        )
        .unwrap();
        let mut k = keys16.clone();
        e.sort(&mut k);
        k
    });

    let std_median_ms = std_r.median_ms();
    let std_ms = debiased_ms(&std_r, clone_ms);
    let native_ms = debiased_ms(&native_r, clone_ms);
    let legacy_ms = debiased_ms(&legacy_r, clone_ms);
    let native_vs_std = std_ms / native_ms;
    let native_vs_legacy = legacy_ms / native_ms;
    println!(
        "    16M uniform (clone-debiased): std {:.1} Mkeys/s | native {:.1} Mkeys/s \
         ({native_vs_std:.2}x std, {native_vs_legacy:.2}x pre-PR config)",
        mkeys_s(GATE_N, std_ms),
        mkeys_s(GATE_N, native_ms),
    );
    results.push(clone_r);
    results.push(std_r);
    results.push(native_r);
    results.push(legacy_r);

    // --- radix vs bitonic tile kernel (Step 2's inner loop) ----------
    let tile = 2048usize;
    let tile_n = if fast { 1 << 19 } else { 1 << 21 };
    let tile_keys = Distribution::Uniform.generate(tile_n, 2);
    let tile_clone_r = bencher.bench(format!("hot/tile_clone/n={tile_n}"), || tile_keys.clone());
    let tile_clone_ms = tile_clone_r.median_ms();
    let bitonic_tile_r = bencher.bench(format!("hot/bitonic_tiles/t={tile}"), || {
        let mut k = tile_keys.clone();
        for t in k.chunks_exact_mut(tile) {
            bitonic::sort_slice(t);
        }
        k
    });
    let mut scratch: Vec<u32> = Vec::new();
    let radix_tile_r = bencher.bench(format!("hot/radix_tiles/t={tile}"), || {
        let mut k = tile_keys.clone();
        for t in k.chunks_exact_mut(tile) {
            radix::radix_tile_sort(t, &mut scratch);
        }
        k
    });
    let tile_speedup =
        debiased_ms(&bitonic_tile_r, tile_clone_ms) / debiased_ms(&radix_tile_r, tile_clone_ms);
    println!("    tile kernel (t={tile}): radix {tile_speedup:.2}x over bitonic");
    let bitonic_tile_ms = bitonic_tile_r.median_ms();
    let radix_tile_ms = radix_tile_r.median_ms();
    results.push(tile_clone_r);
    results.push(bitonic_tile_r);
    results.push(radix_tile_r);

    // --- arena on/off through the executed Algorithm 1 ---------------
    let arena_n = 1 << 20;
    let arena_keys = Distribution::Uniform.generate(arena_n, 3);
    let sorter = BucketSort::new(BucketSortParams::default());
    let warm_ctx = ExecContext::default();
    {
        let mut k = arena_keys.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort_in(&mut k, &mut sim, &warm_ctx).unwrap();
    }
    let arena_warm_r = bencher.bench("hot/bucket_sort_arena_warm/n=1M", || {
        let mut k = arena_keys.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort_in(&mut k, &mut sim, &warm_ctx).unwrap();
        k
    });
    let arena_cold_r = bencher.bench("hot/bucket_sort_arena_cold/n=1M", || {
        let mut k = arena_keys.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        // A fresh context per sort = the pre-PR allocation behaviour.
        sorter
            .sort_in(&mut k, &mut sim, &ExecContext::default())
            .unwrap();
        k
    });
    let arena_speedup = arena_cold_r.median_ms() / arena_warm_r.median_ms().max(1e-3);
    println!("    arena reuse at 1M keys: warm {arena_speedup:.2}x over cold");
    let (arena_warm_ms, arena_cold_ms) = (arena_warm_r.median_ms(), arena_cold_r.median_ms());
    results.push(arena_warm_r);
    results.push(arena_cold_r);

    // --- service end-to-end: batching overhead vs direct engine ------
    {
        let n = 1 << 18;
        let keys = Distribution::Uniform.generate(n, 4);
        let direct = bencher.bench("hot/engine_direct/n=256K", || {
            let mut k = keys.clone();
            engine.sort(&mut k);
            k
        });
        let client = SortService::start(ServiceConfig::default()).unwrap();
        let service = bencher.bench("hot/service_roundtrip/n=256K", || {
            client.sort_keys(keys.clone()).unwrap()
        });
        let overhead =
            (service.median_ms() - direct.median_ms()) / direct.median_ms().max(1e-3) * 100.0;
        println!("    service overhead over direct engine: {overhead:.1}%");
        client.shutdown();
        results.push(direct);
        results.push(service);
    }

    // --- output-equality smoke + JSON report -------------------------
    let agree = kernels_agree();
    println!("    kernels agree byte-for-byte: {agree}");

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("median_ms", Json::num(r.median_ms())),
                ("mean_ms", Json::num(r.mean_ms())),
                ("min_ms", Json::num(r.min_ms())),
                ("samples", Json::num(r.samples_ms.len() as f64)),
            ])
        })
        .collect();
    let report = Json::obj(vec![
        ("bench", Json::str("hot_paths")),
        ("mode", Json::str(if fast { "smoke" } else { "full" })),
        ("engine", Json::str("native")),
        ("gate_n", Json::num(GATE_N as f64)),
        ("clone_median_ms", Json::num(clone_ms)),
        ("std_median_ms", Json::num(std_median_ms)),
        ("std_debiased_mkeys_s", Json::num(mkeys_s(GATE_N, std_ms))),
        (
            "native_debiased_mkeys_s",
            Json::num(mkeys_s(GATE_N, native_ms)),
        ),
        ("native_vs_std_speedup", Json::num(native_vs_std)),
        ("native_vs_legacy_speedup", Json::num(native_vs_legacy)),
        (
            "tile",
            Json::obj(vec![
                ("tile", Json::num(tile as f64)),
                ("n", Json::num(tile_n as f64)),
                ("bitonic_median_ms", Json::num(bitonic_tile_ms)),
                ("radix_median_ms", Json::num(radix_tile_ms)),
                ("radix_speedup", Json::num(tile_speedup)),
            ]),
        ),
        (
            "arena",
            Json::obj(vec![
                ("n", Json::num(arena_n as f64)),
                ("warm_median_ms", Json::num(arena_warm_ms)),
                ("cold_median_ms", Json::num(arena_cold_ms)),
                ("warm_speedup", Json::num(arena_speedup)),
            ]),
        ),
        ("kernels_agree", Json::Bool(agree)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_hot_paths.json", report.to_string_pretty())
        .expect("write BENCH_hot_paths.json");
    println!("→ BENCH_hot_paths.json");

    common::emit_measurements("hot_paths", &results);

    if !agree {
        eprintln!("FAIL: radix and bitonic kernels disagree");
        std::process::exit(1);
    }
}

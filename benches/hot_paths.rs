//! Hot-path wall-clock benchmarks — the §Perf working set: the native
//! engine against `slice::sort_unstable`, its phases, the bitonic tile
//! kernel, and the end-to-end service (batching overhead).

mod common;

use gpu_bucket_sort::algos::bitonic;
use gpu_bucket_sort::config::ServiceConfig;
use gpu_bucket_sort::coordinator::SortService;
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    let bencher = Bencher::from_env();
    let mut results = Vec::new();

    // --- native engine vs std sort across sizes --------------------
    let engine = NativeEngine::new(NativeParams::default()).unwrap();
    println!("native engine: {} workers", engine.workers());
    for n in [1usize << 20, 1 << 22, 1 << 24] {
        let keys = Distribution::Uniform.generate(n, 1);

        let std_r = bencher.bench(format!("hot/std_sort/n={n}"), || {
            let mut k = keys.clone();
            k.sort_unstable();
            k
        });
        let nat_r = bencher.bench(format!("hot/native/n={n}"), || {
            let mut k = keys.clone();
            engine.sort(&mut k);
            k
        });
        let speedup = std_r.median_ms() / nat_r.median_ms();
        println!("    n={n}: native speedup over std {speedup:.2}x");
        results.push(std_r);
        results.push(nat_r);
    }

    // --- clone baseline (so sort numbers can be de-biased) ---------
    {
        let keys = Distribution::Uniform.generate(1 << 24, 1);
        results.push(bencher.bench("hot/clone_only/n=16M", || keys.clone()));
    }

    // --- bitonic tile kernel (Step 2's inner loop) -----------------
    for tile in [512usize, 2048] {
        let keys = Distribution::Uniform.generate(tile, 2);
        results.push(bencher.bench(format!("hot/bitonic_tile/t={tile}"), || {
            let mut k = keys.clone();
            bitonic::sort_slice(&mut k);
            k
        }));
    }

    // --- service end-to-end: batching overhead vs direct engine ----
    {
        let n = 1 << 18;
        let keys = Distribution::Uniform.generate(n, 3);
        let direct = bencher.bench("hot/engine_direct/n=256K", || {
            let mut k = keys.clone();
            engine.sort(&mut k);
            k
        });
        let client = SortService::start(ServiceConfig::default()).unwrap();
        let service = bencher.bench("hot/service_roundtrip/n=256K", || {
            client.sort_keys(keys.clone()).unwrap()
        });
        let overhead =
            (service.median_ms() - direct.median_ms()) / direct.median_ms() * 100.0;
        println!("    service overhead over direct engine: {overhead:.1}%");
        client.shutdown();
        results.push(direct);
        results.push(service);
    }

    common::emit_measurements("hot_paths", &results);
}

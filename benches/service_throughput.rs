//! Service throughput vs scheduler worker count — the perf artifact
//! behind the multi-worker scheduler.
//!
//! Drives the full request path (client → intake → batcher → scheduler
//! → worker pool) with M concurrent submitters over the six-distribution
//! robustness suite, at 1/2/4 workers. Each worker runs a
//! [`PacedSimEngine`]: output computed on the host, *occupancy* priced
//! by the analytic cost model of one simulated GTX 285 — so a worker
//! stands in for one device and aggregate throughput scales with
//! simulated devices, not host cores. The deterministic cost model is
//! what makes the numbers stable run to run (the paper's
//! data-independence claim, applied to benchmarking).
//!
//! Emits a machine-readable JSON report to
//! `results/service_throughput.json` (validated by CI's `bench-smoke`
//! job) and **fails** unless 4 workers deliver ≥ 2× the 1-worker
//! throughput on the uniform distribution — the benchmark gate.
//!
//! `GBS_BENCH_FAST=1` selects the smoke profile (smaller n, fewer
//! requests) used by CI.

use gpu_bucket_sort::config::{BatchConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{PacedSimEngine, SortEngine, SortRequest, SortService};
use gpu_bucket_sort::sim::GpuModel;
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::Key;
use std::time::Instant;

/// Pacing multiplier over the Table 1 device estimate: keeps the priced
/// device time comfortably above per-request host work (even on a
/// 2-core CI box), so worker scaling — not host core count — dominates
/// the measurement.
const TIME_SCALE: f64 = 4.0;

/// The simulated device each worker stands in for.
const DEVICE: GpuModel = GpuModel::Gtx285_2G;

struct Profile {
    mode: &'static str,
    keys_per_request: usize,
    submitters: usize,
    requests_per_submitter: usize,
}

impl Profile {
    fn from_env() -> Profile {
        if std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1") {
            Profile {
                mode: "smoke",
                keys_per_request: 1 << 18,
                submitters: 6,
                requests_per_submitter: 3,
            }
        } else {
            Profile {
                mode: "full",
                keys_per_request: 1 << 20,
                submitters: 8,
                requests_per_submitter: 8,
            }
        }
    }
}

struct RunResult {
    distribution: Distribution,
    workers: usize,
    requests: usize,
    total_keys: usize,
    wall_ms: f64,
    throughput_mkeys_s: f64,
    throughput_req_s: f64,
    p50_request_ms: f64,
    p99_request_ms: f64,
    queue_depth_peak: u64,
}

fn run_one(profile: &Profile, dist: Distribution, workers: usize) -> RunResult {
    let cfg = ServiceConfig {
        workers,
        verify: false,
        batch: BatchConfig {
            // One request per batch: every dispatch is one device pass,
            // so the worker pool — not batch packing — is what varies
            // between runs.
            max_batch_requests: 1,
            max_wait_ms: 0,
            ..BatchConfig::default()
        },
        ..ServiceConfig::default()
    };
    let client =
        SortService::start_with_worker_factory(cfg, |cfg: &ServiceConfig, _worker: usize| {
            let engine = PacedSimEngine::new(DEVICE, cfg.sort, TIME_SCALE)?;
            Ok(Box::new(engine) as Box<dyn SortEngine>)
        })
        .expect("service starts");

    // Pre-generate every input so generation cost never shadows the
    // service under test.
    let inputs: Vec<Vec<Vec<Key>>> = (0..profile.submitters)
        .map(|s| {
            (0..profile.requests_per_submitter)
                .map(|r| {
                    dist.generate(
                        profile.keys_per_request,
                        (s * 1000 + r) as u64 + 1,
                    )
                })
                .collect()
        })
        .collect();
    let requests = profile.submitters * profile.requests_per_submitter;
    let total_keys = requests * profile.keys_per_request;

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for submitter_inputs in inputs {
            let client = client.clone();
            scope.spawn(move || {
                for keys in submitter_inputs {
                    let out = client
                        .sort(SortRequest::new(keys))
                        .expect("request succeeds");
                    assert!(gpu_bucket_sort::is_sorted(out.keys_u32()));
                }
            });
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let snap = client.shutdown();

    let latency = snap
        .timers
        .get("request_latency")
        .expect("request_latency recorded");
    assert_eq!(
        snap.counters["requests_completed"], requests as u64,
        "every request completed"
    );
    RunResult {
        distribution: dist,
        workers,
        requests,
        total_keys,
        wall_ms,
        throughput_mkeys_s: total_keys as f64 / wall_ms * 1e3 / 1e6,
        throughput_req_s: requests as f64 / wall_ms * 1e3,
        p50_request_ms: latency.quantile_ms(0.5),
        p99_request_ms: latency.quantile_ms(0.99),
        queue_depth_peak: snap
            .counters
            .get("scheduler_queue_depth_peak")
            .copied()
            .unwrap_or(0),
    }
}

fn result_json(r: &RunResult) -> Json {
    Json::obj(vec![
        ("distribution", Json::str(r.distribution.to_string())),
        ("workers", Json::num(r.workers as f64)),
        ("requests", Json::num(r.requests as f64)),
        ("total_keys", Json::num(r.total_keys as f64)),
        ("wall_ms", Json::num(r.wall_ms)),
        ("throughput_mkeys_s", Json::num(r.throughput_mkeys_s)),
        ("throughput_req_s", Json::num(r.throughput_req_s)),
        ("p50_request_ms", Json::num(r.p50_request_ms)),
        ("p99_request_ms", Json::num(r.p99_request_ms)),
        ("queue_depth_peak", Json::num(r.queue_depth_peak as f64)),
    ])
}

fn main() {
    let profile = Profile::from_env();
    println!(
        "service_throughput [{}]: {} submitters × {} requests × {} keys, paced {DEVICE} ×{TIME_SCALE}",
        profile.mode,
        profile.submitters,
        profile.requests_per_submitter,
        profile.keys_per_request
    );

    let mut results: Vec<RunResult> = Vec::new();
    for dist in Distribution::ROBUSTNESS_SUITE {
        // The uniform headline gets the full 1→2→4 ladder; the rest
        // pin the endpoints.
        let ladder: &[usize] = if dist == Distribution::Uniform {
            &[1, 2, 4]
        } else {
            &[1, 4]
        };
        for &workers in ladder {
            let r = run_one(&profile, dist, workers);
            println!(
                "  {:<14} workers={}  {:>8.1} ms  {:>7.1} Mkeys/s  p50 {:>7.1} ms  p99 {:>7.1} ms",
                r.distribution.to_string(),
                r.workers,
                r.wall_ms,
                r.throughput_mkeys_s,
                r.p50_request_ms,
                r.p99_request_ms
            );
            results.push(r);
        }
    }

    // Scaling: 4-worker vs 1-worker throughput per distribution.
    let mut scaling = Vec::new();
    let mut uniform_speedup = 0.0;
    for dist in Distribution::ROBUSTNESS_SUITE {
        let thr = |workers: usize| {
            results
                .iter()
                .find(|r| r.distribution == dist && r.workers == workers)
                .map(|r| r.throughput_mkeys_s)
        };
        let (Some(base), Some(top)) = (thr(1), thr(4)) else {
            continue;
        };
        let speedup = top / base;
        if dist == Distribution::Uniform {
            uniform_speedup = speedup;
        }
        println!("  {:<14} 4-worker speedup: {speedup:.2}×", dist.to_string());
        scaling.push(Json::obj(vec![
            ("distribution", Json::str(dist.to_string())),
            ("workers", Json::num(4.0)),
            ("baseline_workers", Json::num(1.0)),
            ("speedup", Json::num(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::str("service_throughput")),
        ("schema_version", Json::num(1.0)),
        ("mode", Json::str(profile.mode)),
        ("engine", Json::str("sim-paced")),
        ("device", Json::str(DEVICE.id())),
        ("time_scale", Json::num(TIME_SCALE)),
        ("submitters", Json::num(profile.submitters as f64)),
        (
            "requests_per_submitter",
            Json::num(profile.requests_per_submitter as f64),
        ),
        (
            "keys_per_request",
            Json::num(profile.keys_per_request as f64),
        ),
        ("results", Json::Arr(results.iter().map(result_json).collect())),
        ("scaling", Json::Arr(scaling)),
    ]);

    let out_dir = std::path::Path::new("results");
    std::fs::create_dir_all(out_dir).expect("create results/");
    let path = out_dir.join("service_throughput.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write JSON report");
    println!("→ {}", path.display());

    // The benchmark gate: the scheduler must actually scale.
    assert!(
        uniform_speedup >= 2.0,
        "4 workers delivered only {uniform_speedup:.2}× the 1-worker throughput \
         on uniform (gate: ≥ 2×)"
    );
    println!("gate OK: uniform 4-worker speedup {uniform_speedup:.2}× ≥ 2×");
}

//! Figure 6: GTX 285 — GPU Bucket Sort vs Randomized Sample Sort [9]
//! vs Thrust Merge [14]: both sample sorts comparable, Thrust Merge
//! clearly behind, and the three methods' memory ceilings (256M / 32M /
//! 16M).

mod common;

use gpu_bucket_sort::algos::Algorithm;
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale table.
    common::emit_table(&exp::fig6_gtx285(&exp::paper_n_ladder(256 << 20)));

    // (b) Executed head-to-head at n = 1M on the simulated GTX 285.
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 6);
    let bencher = Bencher::from_env();
    let mut results = Vec::new();
    for algo in Algorithm::ALL {
        let mut est = 0.0;
        let r = bencher.bench(format!("fig6/exec/{algo}"), || {
            let mut k = keys.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            est = algo.run(&mut k, &mut sim).unwrap();
            k
        });
        println!("    {algo}: simulated estimate {est:.2} ms");
        results.push(r);
    }
    common::emit_measurements("fig6", &results);
}

//! Typed-key throughput — the perf artifact behind the `SortKey`
//! redesign.
//!
//! Measures the native engine (the production path) across the typed
//! surface: `u32` vs `u64` vs `f32` keys, key-only vs key–value, on the
//! uniform distribution, plus the simulated device's *estimated* time
//! at each width (the ledger's key-width scaling made visible).
//!
//! Emits a machine-readable JSON report to `results/typed_keys.json`
//! (validated by CI's `bench-smoke` job) and **fails** unless
//! * the u32 key-only path stays within 1.5× of plain
//!   `slice::sort_unstable` (the generic bit-comparison surface must
//!   not tax the classic path), and
//! * every typed variant actually sorted (self-checked).
//!
//! `GBS_BENCH_FAST=1` selects the smoke profile (smaller n) used by CI.

mod common;

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::{BenchResult, Bencher};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{is_sorted_permutation, SortKey};

struct Row {
    key_type: &'static str,
    variant: &'static str,
    n: usize,
    median_ms: f64,
    throughput_mkeys_s: f64,
    sim_estimated_ms: f64,
}

fn bench_type<K: SortKey>(
    key_type: &'static str,
    n: usize,
    bencher: &Bencher,
    engine: &NativeEngine,
    results: &mut Vec<BenchResult>,
    rows: &mut Vec<Row>,
) {
    let keys: Vec<K> = Distribution::Uniform.generate_typed(n, 1);

    // Simulated-device estimate at this key width (analytic, instant):
    // the ledger accounting scales with SortKey::WIDTH_BYTES.
    let sim_ms = |elem_bytes: usize| {
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        BucketSort::new(BucketSortParams::default())
            .sort_analytic_bytes(n, elem_bytes, &mut sim)
            .expect("fits the device");
        sim.estimated_ms()
    };

    // Key-only.
    let r = bencher.bench(format!("typed/{key_type}/key_only/n={n}"), || {
        let mut k = keys.clone();
        engine.sort(&mut k);
        k
    });
    {
        let mut k = keys.clone();
        engine.sort(&mut k);
        assert!(is_sorted_permutation(&keys, &k), "{key_type} key-only");
    }
    rows.push(Row {
        key_type,
        variant: "key_only",
        n,
        median_ms: r.median_ms(),
        throughput_mkeys_s: n as f64 / r.median_ms() / 1e3,
        sim_estimated_ms: sim_ms(K::WIDTH_BYTES),
    });
    results.push(r);

    // Key–value (u64 payload permuted via the Record path).
    let payload: Vec<u64> = (0..n as u64).collect();
    let r = bencher.bench(format!("typed/{key_type}/key_value/n={n}"), || {
        let mut k = keys.clone();
        let mut p = payload.clone();
        engine.sort_pairs(&mut k, &mut p).expect("pairs sort");
        (k, p)
    });
    {
        let mut k = keys.clone();
        let mut p = payload.clone();
        engine.sort_pairs(&mut k, &mut p).unwrap();
        assert!(is_sorted_permutation(&keys, &k), "{key_type} key-value");
        for (key, idx) in k.iter().zip(&p) {
            assert!(
                key.key_cmp(&keys[*idx as usize]).is_eq(),
                "{key_type}: payload divorced from key"
            );
        }
    }
    rows.push(Row {
        key_type,
        variant: "key_value",
        n,
        median_ms: r.median_ms(),
        throughput_mkeys_s: n as f64 / r.median_ms() / 1e3,
        sim_estimated_ms: sim_ms(K::WIDTH_BYTES + 4),
    });
    results.push(r);
}

fn main() {
    let fast = std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 1 << 18 } else { 1 << 22 };
    let bencher = Bencher::from_env();
    let engine = NativeEngine::new(NativeParams::default()).unwrap();
    println!(
        "typed_keys [{}]: n={n}, native engine with {} workers",
        if fast { "smoke" } else { "full" },
        engine.workers()
    );

    let mut results: Vec<BenchResult> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    // Baseline: plain std sort of the classic u32 keys.
    let base_keys: Vec<u32> = Distribution::Uniform.generate_typed(n, 1);
    let std_r = bencher.bench(format!("typed/u32/std_sort/n={n}"), || {
        let mut k = base_keys.clone();
        k.sort_unstable();
        k
    });
    let std_median = std_r.median_ms();
    results.push(std_r);

    bench_type::<u32>("u32", n, &bencher, &engine, &mut results, &mut rows);
    bench_type::<u64>("u64", n, &bencher, &engine, &mut results, &mut rows);
    bench_type::<f32>("f32", n, &bencher, &engine, &mut results, &mut rows);

    for r in &rows {
        println!(
            "  {:<4} {:<9} {:>8.2} ms  {:>7.1} Mkeys/s  (sim est {:>8.2} ms)",
            r.key_type, r.variant, r.median_ms, r.throughput_mkeys_s, r.sim_estimated_ms
        );
    }

    // The gate: the typed surface must not tax the classic u32 path.
    // The native engine beats std sort at full size on multicore hosts;
    // allow 1.5× headroom so 2-core CI boxes and smoke sizes pass while
    // a genuine generic-dispatch regression still fails.
    let u32_key_only = rows
        .iter()
        .find(|r| r.key_type == "u32" && r.variant == "key_only")
        .expect("u32 row exists");
    let ratio = u32_key_only.median_ms / std_median;
    println!("  u32 key-only vs std sort: {ratio:.2}×");

    let row_json = |r: &Row| {
        Json::obj(vec![
            ("key_type", Json::str(r.key_type)),
            ("variant", Json::str(r.variant)),
            ("n", Json::num(r.n as f64)),
            ("median_ms", Json::num(r.median_ms)),
            ("throughput_mkeys_s", Json::num(r.throughput_mkeys_s)),
            ("sim_estimated_ms", Json::num(r.sim_estimated_ms)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::str("typed_keys")),
        ("schema_version", Json::num(1.0)),
        ("mode", Json::str(if fast { "smoke" } else { "full" })),
        ("engine", Json::str("native")),
        ("n", Json::num(n as f64)),
        ("std_sort_median_ms", Json::num(std_median)),
        ("u32_vs_std_ratio", Json::num(ratio)),
        ("results", Json::Arr(rows.iter().map(row_json).collect())),
    ]);
    let out_dir = std::path::Path::new("results");
    std::fs::create_dir_all(out_dir).expect("create results/");
    let path = out_dir.join("typed_keys.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write JSON report");
    println!("→ {}", path.display());

    common::emit_measurements("typed_keys", &results);

    assert!(
        ratio <= 1.5,
        "typed u32 key-only path regressed to {ratio:.2}× of std sort"
    );
}

//! Distribution robustness benchmark (§5 / X1): executed runs of both
//! sample sorts across the input-distribution suite — the deterministic
//! method's estimates stay flat while the randomized baseline
//! fluctuates. Also wall-clock-measures the native engine per
//! distribution (host-side robustness).

mod common;

use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Simulated-device robustness table (executed algorithms).
    let (table, gbs_spread, rss_spread) = exp::robustness(1 << 19, 7);
    common::emit_table(&table);
    println!(
        "spread (max/min − 1): deterministic {gbs_spread:.4}, randomized {rss_spread:.4}\n"
    );

    // (b) Native engine wall time per distribution.
    let engine = NativeEngine::new(NativeParams::default()).unwrap();
    let bencher = Bencher::from_env();
    let n = 1 << 22;
    let mut results = Vec::new();
    for dist in Distribution::ROBUSTNESS_SUITE {
        let keys = dist.generate(n, 11);
        results.push(bencher.bench(format!("dist/native/{dist}"), || {
            let mut k = keys.clone();
            engine.sort(&mut k);
            k
        }));
    }
    common::emit_measurements("distributions", &results);
}

//! Figure 3: total runtime of Algorithm 1 as a function of the sample
//! size s, for fixed n — the trade-off that selects the paper's s = 64.
//!
//! Regenerates the simulated paper-scale series (n ∈ {32M, 64M, 128M})
//! and wall-clock-measures the executed algorithm's s-sweep at a
//! host-feasible n, checking the same U-shape appears in both.

mod common;

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // (a) Paper-scale table.
    common::emit_table(&exp::fig3_sample_size(&exp::FIG3_NS, &exp::FIG3_S_VALUES));

    // (b) Executed sweep at n = 1M: wall time of the host execution and
    // the simulated estimate per s.
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 3);
    let bencher = Bencher::from_env();
    let mut results = Vec::new();
    println!("executed s-sweep at n = {n} (host wall + simulated estimate):");
    for s in exp::FIG3_S_VALUES {
        let sorter = BucketSort::new(BucketSortParams { tile: 2048, s });
        let mut est = 0.0;
        let r = bencher.bench(format!("fig3/exec/s={s}"), || {
            let mut k = keys.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let report = sorter.sort(&mut k, &mut sim).unwrap();
            est = report.total_estimated_ms(sim.spec());
            k
        });
        println!("    s={s:<4} simulated estimate {est:8.2} ms");
        results.push(r);
    }
    common::emit_measurements("fig3", &results);
}

//! Network tier throughput: a multi-process load harness for the TCP
//! sort server.
//!
//! The parent process re-executes itself (`GBS_NET_ROLE`) as one
//! **server** subprocess (2-worker native service behind
//! [`NetServer`]) and M **client** subprocesses, so the measurement
//! crosses real process and socket boundaries — kernel TCP, frame
//! codec, chunked streaming and credit flow control all on the path,
//! with no shared memory shortcuts.
//!
//! Each client performs sequential `sort` round trips over one
//! connection, checks every response against a local `sort_unstable`
//! of the same input (**byte identity** is a gate, not a metric), and
//! reports per-request latencies as JSON on stdout. The parent
//! aggregates p50/p99 latency and Mkeys/s per client count and emits
//! `BENCH_net.json` at the repo root — the perf-trajectory artifact
//! validated by CI's `bench-smoke` job.
//!
//! Gates: responses byte-identical in every client process, and this
//! light sequential load must finish with **zero** `Busy` sheds.
//!
//! `GBS_BENCH_FAST=1` selects the smoke profile used by CI.

use gpu_bucket_sort::config::{NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::net::{NetClient, NetServer};
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use std::io::{BufRead, BufReader, Read as _};
use std::process::{Command, Stdio};
use std::time::Instant;

/// Engine workers behind the server subprocess.
const WORKERS: usize = 2;

struct Profile {
    mode: &'static str,
    requests_per_client: usize,
    keys_per_request: usize,
    client_counts: &'static [usize],
}

impl Profile {
    fn from_env() -> Profile {
        if std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1") {
            Profile {
                mode: "smoke",
                requests_per_client: 8,
                keys_per_request: 50_000,
                client_counts: &[1, 4],
            }
        } else {
            Profile {
                mode: "full",
                requests_per_client: 32,
                keys_per_request: 500_000,
                client_counts: &[1, 4],
            }
        }
    }
}

/// `GBS_NET_ROLE=server`: serve until a client sends `Drain`, then
/// report the shed counters on stdout for the parent to scrape.
fn run_server() {
    use std::io::Write as _;
    let cfg = ServiceConfig {
        workers: WORKERS,
        verify: false,
        ..ServiceConfig::default()
    };
    let service = SortService::start(cfg).expect("service starts");
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).expect("bind");
    println!("GBS_NET_ADDR {}", server.local_addr());
    std::io::stdout().flush().expect("flush addr line");
    server.wait_for_drain_request(None);
    let snap = server.shutdown();
    let shed = snap.counters.get("net_shed_busy").copied().unwrap_or(0);
    let responses = snap.counters.get("net_responses").copied().unwrap_or(0);
    println!("GBS_NET_DONE shed_busy={shed} responses={responses}");
}

/// `GBS_NET_ROLE=client`: sequential sort round trips, byte-identity
/// checked against a local sort, latencies reported as one JSON line.
fn run_client() {
    let addr = std::env::var("GBS_NET_ADDR").expect("GBS_NET_ADDR set");
    let env_usize = |key: &str| -> usize {
        std::env::var(key).expect(key).parse().expect("numeric env")
    };
    let requests = env_usize("GBS_NET_REQUESTS");
    let n = env_usize("GBS_NET_N");
    let seed = env_usize("GBS_NET_SEED") as u64;

    let client = NetClient::connect(&addr, 1, NetConfig::default()).expect("connect");
    let mut latencies = Vec::with_capacity(requests);
    let mut ok = true;
    for r in 0..requests {
        let keys = Distribution::Uniform.generate(n, seed * 10_000 + r as u64 + 1);
        let mut expected = keys.clone();
        expected.sort_unstable();
        let t0 = Instant::now();
        let out = client.sort(SortRequest::new(keys)).expect("sort succeeds");
        latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        ok &= out.keys_u32() == expected.as_slice();
    }
    let report = Json::obj(vec![
        ("ok", Json::Bool(ok)),
        ("keys", Json::num((requests * n) as f64)),
        ("latencies_us", Json::Arr(latencies.iter().map(|&l| Json::num(l)).collect())),
    ]);
    println!("{}", report.to_string_compact());
    assert!(ok, "remote results diverged from the local sort");
}

struct RunResult {
    clients: usize,
    requests: usize,
    wall_ms: f64,
    p50_ms: f64,
    p99_ms: f64,
    mkeys_s: f64,
    shed_busy: u64,
}

/// Nearest-rank percentile over an ascending slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// One load point: a fresh server subprocess, `clients` concurrent
/// client subprocesses, then a graceful drain.
fn run_load(profile: &Profile, clients: usize) -> RunResult {
    let exe = std::env::current_exe().expect("current_exe");
    let mut server = Command::new(&exe)
        .env("GBS_NET_ROLE", "server")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn server");
    let mut server_out = BufReader::new(server.stdout.take().expect("server stdout"));
    let mut line = String::new();
    server_out.read_line(&mut line).expect("read addr line");
    let addr = line
        .strip_prefix("GBS_NET_ADDR ")
        .expect("server announced its address")
        .trim()
        .to_string();
    // Drain the rest of the server's stdout off-thread: the DONE line
    // arrives only after our drain request, and an unread pipe would
    // otherwise deadlock the child at exit.
    let tail = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = server_out.read_to_string(&mut rest);
        rest
    });

    let t0 = Instant::now();
    let children: Vec<_> = (0..clients)
        .map(|c| {
            Command::new(&exe)
                .env("GBS_NET_ROLE", "client")
                .env("GBS_NET_ADDR", &addr)
                .env("GBS_NET_REQUESTS", profile.requests_per_client.to_string())
                .env("GBS_NET_N", profile.keys_per_request.to_string())
                .env("GBS_NET_SEED", (c + 1).to_string())
                .stdout(Stdio::piped())
                .spawn()
                .expect("spawn client")
        })
        .collect();
    let outputs: Vec<_> = children
        .into_iter()
        .map(|child| child.wait_with_output().expect("client exits"))
        .collect();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Stop the server *before* asserting on client results, so a
    // failed gate never leaves an orphaned subprocess behind.
    NetClient::connect(&addr, 1, NetConfig::default())
        .expect("drain connection")
        .drain_server()
        .expect("drain acknowledged");
    let status = server.wait().expect("server exits");
    let rest = tail.join().expect("server output thread");
    assert!(status.success(), "server process failed:\n{rest}");
    let done = rest
        .lines()
        .find(|l| l.starts_with("GBS_NET_DONE"))
        .expect("server DONE line");
    let shed_busy: u64 = done
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("shed_busy="))
        .expect("shed_busy field")
        .parse()
        .expect("shed_busy parses");

    let mut latencies = Vec::new();
    let mut total_keys = 0u64;
    for out in outputs {
        assert!(out.status.success(), "client process failed");
        let text = String::from_utf8_lossy(&out.stdout);
        let json_line = text
            .lines()
            .find(|l| l.starts_with('{'))
            .expect("client JSON line");
        let report = Json::parse(json_line).expect("client JSON parses");
        assert_eq!(
            report.get("ok").and_then(Json::as_bool),
            Some(true),
            "byte identity violated over TCP"
        );
        total_keys += report.get("keys").and_then(Json::as_u64).expect("keys");
        for l in report
            .get("latencies_us")
            .and_then(Json::as_arr)
            .expect("latencies")
        {
            latencies.push(l.as_f64().expect("latency number"));
        }
    }
    latencies.sort_by(f64::total_cmp);
    RunResult {
        clients,
        requests: clients * profile.requests_per_client,
        wall_ms,
        p50_ms: percentile(&latencies, 0.50) / 1e3,
        p99_ms: percentile(&latencies, 0.99) / 1e3,
        mkeys_s: total_keys as f64 / wall_ms * 1e3 / 1e6,
        shed_busy,
    }
}

fn result_json(r: &RunResult) -> Json {
    Json::obj(vec![
        ("clients", Json::num(r.clients as f64)),
        ("requests", Json::num(r.requests as f64)),
        ("wall_ms", Json::num(r.wall_ms)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p99_ms", Json::num(r.p99_ms)),
        ("mkeys_s", Json::num(r.mkeys_s)),
        ("shed_busy", Json::num(r.shed_busy as f64)),
    ])
}

fn main() {
    match std::env::var("GBS_NET_ROLE").as_deref() {
        Ok("server") => return run_server(),
        Ok("client") => return run_client(),
        _ => {}
    }
    let profile = Profile::from_env();
    println!(
        "net_throughput [{}]: {} requests × {} u32 keys per client, {WORKERS} workers, \
         clients ∈ {:?}",
        profile.mode, profile.requests_per_client, profile.keys_per_request, profile.client_counts
    );

    let mut results = Vec::new();
    let mut shed_total = 0u64;
    for &clients in profile.client_counts {
        let r = run_load(&profile, clients);
        println!(
            "  clients={}  {:>8.1} ms  {:>7.2} Mkeys/s  p50 {:>7.1} ms  p99 {:>7.1} ms  shed={}",
            r.clients, r.wall_ms, r.mkeys_s, r.p50_ms, r.p99_ms, r.shed_busy
        );
        shed_total += r.shed_busy;
        results.push(r);
    }

    let report = Json::obj(vec![
        ("bench", Json::str("net_throughput")),
        ("schema_version", Json::num(1.0)),
        ("mode", Json::str(profile.mode)),
        ("engine", Json::str("native")),
        ("workers", Json::num(WORKERS as f64)),
        ("requests_per_client", Json::num(profile.requests_per_client as f64)),
        ("keys_per_request", Json::num(profile.keys_per_request as f64)),
        ("byte_identity", Json::Bool(true)),
        ("shed_light_load", Json::num(shed_total as f64)),
        ("results", Json::Arr(results.iter().map(result_json).collect())),
    ]);
    std::fs::write("BENCH_net.json", report.to_string_pretty()).expect("write BENCH_net.json");
    println!("→ BENCH_net.json");

    // The gates: byte identity held in every client process (asserted
    // above), and light sequential load never tripped the shedder.
    assert_eq!(
        shed_total, 0,
        "light sequential load must not shed Busy ({shed_total} sheds)"
    );
    println!(
        "gate OK: byte identity across {} load points, zero Busy sheds under light load",
        results.len()
    );
}

//! Adaptive front-end benchmarks — the PR-7 per-distribution matrix:
//!
//! * every [`Distribution`] is sorted by three engines — the adaptive
//!   front-end (`KernelKind::Adaptive`, cost model from
//!   `configs/cost_model.json` when present, built-ins otherwise), the
//!   static planned-radix kernel and the static comparison kernel —
//!   and the per-distribution Mkeys/s plus the front-end's recorded
//!   [`PlanChoice`] go into `BENCH_adaptive.json`;
//! * the CI validator (`ci/validate_bench.py`) gates the matrix:
//!   sorted/reverse early exits ≥ 5× the static radix engine,
//!   all-equal/few-unique beating uniform via digit skips,
//!   splitter-killer within 0.9× of uniform, and adaptive never below
//!   0.9× the best static engine on any distribution;
//! * byte-identity is gated *here*: on every distribution the adaptive
//!   output must equal the comparison-kernel output exactly — the
//!   bench exits non-zero otherwise;
//! * the bench doubles as the offline calibrator: it fits the linear
//!   cost-model coefficients from its own measurements and writes the
//!   suggested JSON to `results/cost_model_suggested.json` (compare,
//!   then check in as `configs/cost_model.json` to recalibrate).

mod common;

use gpu_bucket_sort::algos::adaptive::{Choice, CostModel, PlanChoice};
use gpu_bucket_sort::algos::plan;
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::util::bench::Bencher;
use gpu_bucket_sort::util::Json;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{ExecContext, KernelKind};

/// One matrix cell: a distribution measured on all three engines.
struct Cell {
    dist: Distribution,
    adaptive_ms: f64,
    radix_ms: f64,
    comparison_ms: f64,
    choice: Option<PlanChoice>,
    outputs_agree: bool,
}

fn mkeys_s(n: usize, ms: f64) -> f64 {
    n as f64 / ms / 1e3
}

fn main() {
    let bencher = Bencher::from_env();
    let fast = std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 1 << 19 } else { 1 << 21 };

    // The checked-in calibration when present, built-ins otherwise —
    // same resolution order as the service.
    let model_path = "configs/cost_model.json";
    let (cost, model_source) = match CostModel::load(model_path) {
        Ok(m) => (m, model_path),
        Err(_) => (CostModel::default(), "builtin"),
    };
    println!("    cost model: {model_source}");

    let engine = |kernel: KernelKind| {
        NativeEngine::with_context(
            NativeParams::default(),
            ExecContext::new(kernel, 0).with_cost_model(cost),
        )
        .expect("engine construction")
    };
    let adaptive = engine(KernelKind::Adaptive);
    let radix = engine(KernelKind::Radix);
    let comparison = engine(KernelKind::Bitonic);

    let mut results = Vec::new();
    let mut cells = Vec::new();
    for dist in Distribution::ALL {
        let input = dist.generate(n, 7);
        // Warm every arena once, untimed, and take the byte-identity
        // evidence from the warmup outputs.
        let mut a_out = input.clone();
        adaptive.sort(&mut a_out);
        let mut c_out = input.clone();
        comparison.sort(&mut c_out);
        let mut r_out = input.clone();
        radix.sort(&mut r_out);
        let outputs_agree = a_out == c_out && a_out == r_out;

        let clone_r = bencher.bench(format!("adaptive/clone/{dist}"), || input.clone());
        let clone_ms = clone_r.median_ms();
        let a_r = bencher.bench(format!("adaptive/adaptive/{dist}"), || {
            let mut k = input.clone();
            adaptive.sort(&mut k);
            k
        });
        let r_r = bencher.bench(format!("adaptive/radix/{dist}"), || {
            let mut k = input.clone();
            radix.sort(&mut k);
            k
        });
        let c_r = bencher.bench(format!("adaptive/comparison/{dist}"), || {
            let mut k = input.clone();
            comparison.sort(&mut k);
            k
        });
        let cell = Cell {
            dist,
            adaptive_ms: (a_r.median_ms() - clone_ms).max(1e-3),
            radix_ms: (r_r.median_ms() - clone_ms).max(1e-3),
            comparison_ms: (c_r.median_ms() - clone_ms).max(1e-3),
            choice: adaptive.last_plan_choice(),
            outputs_agree,
        };
        let chosen = cell
            .choice
            .map(|c| c.chosen.id())
            .unwrap_or("none");
        println!(
            "    {dist:<20} adaptive {:>8.1} Mkeys/s ({chosen:<18}) | radix {:>8.1} | \
             comparison {:>8.1} | agree {}",
            mkeys_s(n, cell.adaptive_ms),
            mkeys_s(n, cell.radix_ms),
            mkeys_s(n, cell.comparison_ms),
            cell.outputs_agree,
        );
        cells.push(cell);
        results.push(clone_r);
        results.push(a_r);
        results.push(r_r);
        results.push(c_r);
    }

    let totals = adaptive.plan_totals();
    println!(
        "    plan totals: {} requests — {} early-exit sorted, {} early-exit reverse, \
         {} radix, {} comparison",
        totals.requests,
        totals.early_exit_sorted,
        totals.early_exit_reverse,
        totals.chose_radix,
        totals.chose_comparison,
    );

    // ---- offline calibration --------------------------------------
    // Fit the linear coefficients from the matrix itself: the verify
    // scan and reverse from the early-exit rows, the radix per-key-pass
    // rate from uniform, the comparison n·log n rate from uniform.
    // Overheads and the nearly-sorted discount keep their defaults —
    // they need dedicated small-n sweeps, not this matrix.
    let by_dist = |d: Distribution| cells.iter().find(|c| c.dist == d).expect("cell");
    let uniform = by_dist(Distribution::Uniform);
    let sorted = by_dist(Distribution::Sorted);
    let reverse = by_dist(Distribution::ReverseSorted);
    let uniform_passes = plan::plan_for(
        &Distribution::Uniform.generate(n, 7),
        plan::DEFAULT_DIGIT_BITS,
    )
    .passes
    .len()
    .max(1);
    let mut fitted = cost;
    fitted.scan_ns_per_key = (sorted.adaptive_ms * 1e6 / n as f64).max(0.01);
    fitted.reverse_ns_per_key =
        ((reverse.adaptive_ms - sorted.adaptive_ms).max(0.0) * 1e6 / n as f64).max(0.01);
    fitted.radix_ns_per_key_pass =
        (uniform.radix_ms * 1e6 / (n as f64 * uniform_passes as f64)).max(0.01);
    fitted.comparison_ns_per_key_log =
        (uniform.comparison_ms * 1e6 / (n as f64 * (n as f64).log2())).max(0.01);
    let suggested = fitted.to_json().to_string_pretty();
    std::fs::create_dir_all("results").ok();
    match std::fs::write("results/cost_model_suggested.json", &suggested) {
        Ok(()) => println!("→ results/cost_model_suggested.json (calibration)"),
        Err(e) => eprintln!("(calibration write failed: {e})"),
    }

    // ---- report ---------------------------------------------------
    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("distribution", Json::str(c.dist.id())),
                ("n", Json::num(n as f64)),
                ("adaptive_mkeys_s", Json::num(mkeys_s(n, c.adaptive_ms))),
                ("radix_mkeys_s", Json::num(mkeys_s(n, c.radix_ms))),
                (
                    "comparison_mkeys_s",
                    Json::num(mkeys_s(n, c.comparison_ms)),
                ),
                (
                    "chosen",
                    Json::str(c.choice.map(|p| p.chosen.id()).unwrap_or("none")),
                ),
                (
                    "predicted_ms",
                    Json::num(c.choice.map(|p| p.predicted_ms).unwrap_or(-1.0)),
                ),
                (
                    "actual_ms",
                    Json::num(c.choice.map(|p| p.actual_ms).unwrap_or(-1.0)),
                ),
                ("outputs_agree", Json::Bool(c.outputs_agree)),
            ])
        })
        .collect();
    let all_agree = cells.iter().all(|c| c.outputs_agree);
    let early_exits = [Choice::EarlyExitSorted, Choice::EarlyExitReverse];
    let took_early_exits = cells.iter().any(|c| {
        c.choice
            .map(|p| early_exits.contains(&p.chosen))
            .unwrap_or(false)
    });
    let report = Json::obj(vec![
        ("bench", Json::str("adaptive")),
        ("mode", Json::str(if fast { "smoke" } else { "full" })),
        ("engine", Json::str("native")),
        ("n", Json::num(n as f64)),
        ("cost_model", Json::str(model_source)),
        ("digit_bits", Json::num(plan::DEFAULT_DIGIT_BITS as f64)),
        ("outputs_agree", Json::Bool(all_agree)),
        ("took_early_exits", Json::Bool(took_early_exits)),
        (
            "plan_totals",
            Json::obj(vec![
                ("requests", Json::num(totals.requests as f64)),
                (
                    "early_exit_sorted",
                    Json::num(totals.early_exit_sorted as f64),
                ),
                (
                    "early_exit_reverse",
                    Json::num(totals.early_exit_reverse as f64),
                ),
                ("chose_radix", Json::num(totals.chose_radix as f64)),
                (
                    "chose_comparison",
                    Json::num(totals.chose_comparison as f64),
                ),
            ]),
        ),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_adaptive.json", report.to_string_pretty())
        .expect("write BENCH_adaptive.json");
    println!("→ BENCH_adaptive.json");

    common::emit_measurements("adaptive", &results);

    if !all_agree {
        eprintln!("FAIL: adaptive outputs diverged from the static kernels");
        std::process::exit(1);
    }
    if !took_early_exits {
        eprintln!("FAIL: adaptive front-end never took an early exit on sorted/reverse inputs");
        std::process::exit(1);
    }
}

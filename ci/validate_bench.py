#!/usr/bin/env python3
"""Schema-driven validator for the CI bench reports.

One definition of every BENCH_*.json / results/*.json contract the
bench-smoke job gates on, replacing the per-report inline python that
used to be copy-pasted through ci.yml. Each report spec names the
file, the required top-level fields, the row array and its required
fields, and the perf/correctness gates.

Usage:
    python3 ci/validate_bench.py [--sha GITSHA] [REPORT ...]

With no REPORT arguments every known report is validated (and must
exist). A `BENCH_manifest.json` summarising the run — the git SHA plus
every validated report and its headline gate numbers — is written
beside the reports so the whole perf trajectory uploads as one
artifact.
"""

import argparse
import json
import sys


def _rows(report, key):
    rows = report.get(key)
    assert rows, f"no measurement rows under {key!r}"
    return rows


def require(report, fields):
    for field in fields:
        assert field in report, f"missing field {field!r}"


def require_rows(report, key, fields, positive=()):
    for row in _rows(report, key):
        for field in fields:
            assert field in row, f"row missing {field!r}: {row}"
        for field in positive:
            assert row[field] > 0, f"{field} must be > 0: {row}"


# ---------------------------------------------------------------------------
# Per-report gates. Each returns a headline string for the manifest.
# ---------------------------------------------------------------------------


def gate_service_throughput(report):
    require(report, ("bench", "mode", "engine", "results", "scaling"))
    assert report["bench"] == "service_throughput"
    require_rows(
        report,
        "results",
        ("distribution", "workers", "wall_ms", "throughput_mkeys_s",
         "p50_request_ms", "p99_request_ms"),
        positive=("wall_ms", "throughput_mkeys_s"),
    )
    uniform = [s for s in report["scaling"]
               if s["distribution"] == "uniform" and s["workers"] == 4]
    assert uniform, "no uniform 4-worker scaling row"
    speedup = uniform[0]["speedup"]
    assert speedup >= 2.0, f"uniform 4-worker speedup {speedup:.2f} < 2x"
    return f"uniform 4-worker speedup {speedup:.2f}x"


def gate_typed_keys(report):
    require(report, ("bench", "mode", "engine", "n",
                     "std_sort_median_ms", "u32_vs_std_ratio", "results"))
    assert report["bench"] == "typed_keys"
    require_rows(
        report,
        "results",
        ("key_type", "variant", "n", "median_ms",
         "throughput_mkeys_s", "sim_estimated_ms"),
        positive=("median_ms", "throughput_mkeys_s"),
    )
    rows = report["results"]
    # Full coverage: u32/u64/f32 x key-only/key-value.
    combos = {(r["key_type"], r["variant"]) for r in rows}
    for kt in ("u32", "u64", "f32"):
        for variant in ("key_only", "key_value"):
            assert (kt, variant) in combos, f"missing {kt}/{variant}"
    # The ledger's key-width scaling: the simulated estimate for u64
    # keys must exceed u32's at the same n.
    est = {(r["key_type"], r["variant"]): r["sim_estimated_ms"] for r in rows}
    assert est[("u64", "key_only")] > est[("u32", "key_only")]
    assert est[("u32", "key_value")] > est[("u32", "key_only")]
    ratio = report["u32_vs_std_ratio"]
    assert ratio <= 1.5, f"typed u32 path regressed: {ratio:.2f}x of std sort"
    return f"u32 vs std ratio {ratio:.2f}x"


def gate_hot_paths(report):
    require(report, ("bench", "mode", "gate_n", "clone_median_ms",
                     "native_vs_std_speedup", "native_vs_legacy_speedup",
                     "tile", "arena", "kernels_agree", "results"))
    assert report["bench"] == "hot_paths"
    assert report["gate_n"] == 1 << 24
    require_rows(report, "results",
                 ("name", "median_ms", "mean_ms", "min_ms", "samples"))
    for row in report["results"]:
        assert row["median_ms"] >= 0
    # Gate 1: kernel output equality (radix vs bitonic, incl. f32 NaN
    # bits and key-value stability) — checked by the bench, recorded
    # here.
    assert report["kernels_agree"] is True, "radix/bitonic outputs diverged"
    # Gate 2: the native engine must beat slice::sort_unstable at 16M
    # uniform keys (clone-debiased).
    vs_std = report["native_vs_std_speedup"]
    assert vs_std >= 1.0, f"native engine slower than std sort: {vs_std:.2f}x"
    # Gate 3: the arena'd radix path must at least match the pre-PR
    # native configuration (0.9 allows CI noise).
    vs_legacy = report["native_vs_legacy_speedup"]
    assert vs_legacy >= 0.9, f"hot path regressed vs pre-PR config: {vs_legacy:.2f}x"
    # Gate 4: the radix tile kernel must beat the bitonic network.
    tile = report["tile"]["radix_speedup"]
    assert tile > 1.0, f"radix tile kernel not faster: {tile:.2f}x"
    return (f"native {vs_std:.2f}x std, {vs_legacy:.2f}x pre-PR, "
            f"tile radix {tile:.2f}x bitonic")


def gate_planner(report):
    require(report, ("bench", "mode", "digit_bits", "gate_n",
                     "planned_passes", "planned_vs_bytewise",
                     "low_entropy", "dispatch", "kernels_agree", "results"))
    assert report["bench"] == "planner"
    assert report["gate_n"] == 1 << 24
    require_rows(report, "results",
                 ("name", "median_ms", "mean_ms", "min_ms", "samples"))
    for row in report["results"]:
        assert row["median_ms"] >= 0
    # Gate 1: output equality — planned (several digit widths),
    # byte-wise and comparison sorts agree; coalesced responses are
    # byte-identical to per-request responses.
    assert report["kernels_agree"] is True, "planned/byte-wise outputs diverged"
    assert report["dispatch"]["responses_agree"] is True, \
        "coalesced responses diverged from per-request"
    # Gate 2: the wide-digit planner beats the byte-wise kernel at 16M
    # uniform u32 keys (3 passes vs 4 -> headroom over the 1.1x floor).
    kernel = report["planned_vs_bytewise"]
    assert kernel >= 1.1, f"planner only {kernel:.2f}x over byte-wise"
    assert report["planned_passes"] == 3, \
        f"u32 at 11-bit digits must plan 3 passes, got {report['planned_passes']}"
    # Gate 3: constant digits are actually elided on low-entropy keys
    # (16-bit entropy -> 2 of 3 digits survive at 11 bits).
    low = report["low_entropy"]
    assert low["skipped"] >= 1, f"no passes skipped: {low}"
    # Gate 4: coalesced dispatch beats per-request dispatch on the
    # 256 x 64K-key serving batch.
    dispatch = report["dispatch"]["coalesced_vs_per_request"]
    assert dispatch >= 1.5, f"coalescing only {dispatch:.2f}x over per-request"
    return (f"planner {kernel:.2f}x byte-wise, {low['skipped']} low-entropy "
            f"passes skipped, coalesced {dispatch:.2f}x per-request")


def gate_net(report):
    require(report, ("bench", "mode", "workers", "requests_per_client",
                     "keys_per_request", "byte_identity",
                     "shed_light_load", "results"))
    assert report["bench"] == "net_throughput"
    rows = report["results"]
    assert len(rows) >= 2, f"need >= 2 client counts, got {len(rows)}"
    for row in rows:
        for field in ("clients", "requests", "wall_ms", "p50_ms",
                      "p99_ms", "mkeys_s", "shed_busy"):
            assert field in row, f"row missing {field!r}: {row}"
        assert row["wall_ms"] > 0 and row["mkeys_s"] > 0
        assert row["p50_ms"] > 0 and row["p99_ms"] >= row["p50_ms"]
    # Gate 1: every response in every client process was byte-identical
    # to a local sort of the same input.
    assert report["byte_identity"] is True, "byte identity violated over TCP"
    # Gate 2: light sequential load must never trip the shedder — a
    # Busy under these conditions is a flow-control bug.
    shed = report["shed_light_load"]
    assert shed == 0, f"{shed} Busy sheds under light load"
    counts = sorted(r["clients"] for r in rows)
    return f"clients {counts}, byte identity held, zero sheds under light load"


def gate_adaptive(report):
    require(report, ("bench", "mode", "engine", "n", "cost_model",
                     "digit_bits", "outputs_agree", "took_early_exits",
                     "plan_totals", "results"))
    assert report["bench"] == "adaptive"
    require_rows(
        report,
        "results",
        ("distribution", "n", "adaptive_mkeys_s", "radix_mkeys_s",
         "comparison_mkeys_s", "chosen", "predicted_ms", "actual_ms",
         "outputs_agree"),
        positive=("adaptive_mkeys_s", "radix_mkeys_s", "comparison_mkeys_s"),
    )
    # Gate 1: byte identity — on every distribution the adaptive output
    # matched both static kernels (checked by the bench, recorded here).
    assert report["outputs_agree"] is True, "adaptive outputs diverged"
    rows = {r["distribution"]: r for r in report["results"]}
    for dist, row in rows.items():
        assert row["outputs_agree"] is True, f"outputs diverged on {dist}"
    # Full matrix: every distribution the workload generator knows.
    expected = {"uniform", "gaussian", "zipf", "staggered", "sorted",
                "nearly_sorted", "reverse", "all_equal", "two_values",
                "few_unique", "splitter_killer", "nearly_sorted_blocks"}
    assert expected <= set(rows), f"missing distributions: {expected - set(rows)}"

    def mkeys(dist):
        return rows[dist]["adaptive_mkeys_s"]

    # Gate 2: sorted/reverse inputs take the early exits and beat the
    # static radix engine by >= 5x — the whole point of the front-end.
    assert rows["sorted"]["chosen"] == "early_exit_sorted", rows["sorted"]
    assert rows["reverse"]["chosen"] == "early_exit_reverse", rows["reverse"]
    for dist in ("sorted", "reverse"):
        ratio = mkeys(dist) / rows[dist]["radix_mkeys_s"]
        assert ratio >= 5.0, \
            f"{dist}: early exit only {ratio:.2f}x of static radix"
    # Gate 3: degenerate key ranges beat uniform via digit skips (and
    # all-equal's sorted early exit).
    for dist in ("all_equal", "few_unique"):
        assert mkeys(dist) > mkeys("uniform"), \
            f"{dist} ({mkeys(dist):.1f} Mkeys/s) not faster than uniform " \
            f"({mkeys('uniform'):.1f})"
    # Gate 4: the sampling adversary costs at most 10% vs uniform.
    assert mkeys("splitter_killer") >= 0.9 * mkeys("uniform"), \
        f"splitter_killer {mkeys('splitter_killer'):.1f} < 0.9x uniform " \
        f"{mkeys('uniform'):.1f}"
    # Gate 5: adaptive is never a regression — within 0.9x of the best
    # static kernel on every distribution.
    for dist, row in rows.items():
        best = max(row["radix_mkeys_s"], row["comparison_mkeys_s"])
        assert row["adaptive_mkeys_s"] >= 0.9 * best, \
            f"{dist}: adaptive {row['adaptive_mkeys_s']:.1f} < 0.9x best " \
            f"static {best:.1f}"
    sorted_ratio = mkeys("sorted") / rows["sorted"]["radix_mkeys_s"]
    return (f"{len(rows)} distributions, sorted early exit "
            f"{sorted_ratio:.1f}x radix, byte identity held")


def gate_chaos(report):
    require(report, ("bench", "mode", "engine", "requests", "keys_per_request",
                     "byte_identity_violations", "healthy_mkeys_s",
                     "degraded_mkeys_s", "degraded_ratio", "recovery",
                     "results"))
    assert report["bench"] == "chaos_resilience"
    require_rows(report, "results",
                 ("scenario", "wall_ms", "mkeys_s", "p50_ms"),
                 positive=("wall_ms", "mkeys_s"))
    scenarios = {r["scenario"] for r in report["results"]}
    assert {"healthy", "degraded"} <= scenarios, \
        f"missing scenarios: {scenarios}"
    # Gate 1: recovery never changes bytes — every response under every
    # fault (device loss, socket cut, resubmission) matched a local sort.
    violations = report["byte_identity_violations"]
    assert violations == 0, f"{violations} byte-identity violations under chaos"
    # Gate 2: losing 1 of 4 devices costs at most a bounded throughput
    # slice — failover must re-plan, not serialize.
    ratio = report["degraded_ratio"]
    assert ratio >= 0.6, f"degraded pool only {ratio:.2f}x healthy throughput"
    # Gate 3: the seeded socket cut actually exercised the reconnect +
    # idempotent-resubmit path (a green run that never reconnected
    # proves nothing).
    rec = report["recovery"]
    for field in ("reconnects", "resubmits", "recovered_request_ms",
                  "median_healthy_ms"):
        assert field in rec, f"recovery missing {field!r}: {rec}"
    assert rec["reconnects"] >= 1, "the socket cut never forced a reconnect"
    assert rec["resubmits"] >= 1, "the cut request was never resubmitted"
    return (f"degraded {ratio:.2f}x healthy, 0 byte violations, "
            f"{rec['reconnects']} reconnect(s) ridden through")


def gate_cluster(report):
    require(report, ("bench", "mode", "nodes", "clients", "requests",
                     "keys_per_request", "byte_identity_violations",
                     "failed_requests", "healthy_mkeys_s",
                     "degraded_mkeys_s", "degraded_ratio", "failover",
                     "results"))
    assert report["bench"] == "cluster_failover"
    require_rows(report, "results",
                 ("scenario", "wall_ms", "mkeys_s", "p50_ms"),
                 positive=("wall_ms", "mkeys_s"))
    scenarios = {r["scenario"] for r in report["results"]}
    assert {"healthy", "one_node_killed"} <= scenarios, \
        f"missing scenarios: {scenarios}"
    # Gate 1: failover never changes bytes — every response, including
    # the resubmitted ones, matched a local sort.
    violations = report["byte_identity_violations"]
    assert violations == 0, \
        f"{violations} byte-identity violations across the cluster"
    # Gate 2: node death is invisible to callers — zero failed client
    # requests across both scenarios.
    failed = report["failed_requests"]
    assert failed == 0, f"{failed} client request(s) failed despite failover"
    # Gate 3: losing 1 of 3 nodes costs at most half the throughput.
    ratio = report["degraded_ratio"]
    assert ratio >= 0.5, f"one-node-killed only {ratio:.2f}x healthy throughput"
    # Gate 4: the kill actually landed on a routed node — a run where
    # nothing failed over proves nothing.
    fo = report["failover"]
    for field in ("failovers", "max_failover_ms", "healthy_p50_ms"):
        assert field in fo, f"failover missing {field!r}: {fo}"
    assert fo["failovers"] >= 1, "the killed node was never routed to"
    return (f"one node killed: {ratio:.2f}x healthy, 0 failed requests, "
            f"0 byte violations, {fo['failovers']:.0f} failover(s)")


REPORTS = {
    "service_throughput": ("results/service_throughput.json", gate_service_throughput),
    "typed_keys": ("results/typed_keys.json", gate_typed_keys),
    "hot_paths": ("BENCH_hot_paths.json", gate_hot_paths),
    "planner": ("BENCH_planner.json", gate_planner),
    "net": ("BENCH_net.json", gate_net),
    "adaptive": ("BENCH_adaptive.json", gate_adaptive),
    "chaos": ("BENCH_chaos.json", gate_chaos),
    "cluster": ("BENCH_cluster.json", gate_cluster),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("reports", nargs="*", metavar="REPORT",
                    help=f"reports to validate (default: all of "
                         f"{', '.join(REPORTS)})")
    ap.add_argument("--sha", default="unknown",
                    help="git SHA embedded in BENCH_manifest.json")
    ap.add_argument("--manifest", default="BENCH_manifest.json",
                    help="manifest output path ('' to skip)")
    args = ap.parse_args()
    for name in args.reports:
        if name not in REPORTS:
            ap.error(f"unknown report {name!r} (choose from {', '.join(REPORTS)})")
    names = args.reports or list(REPORTS)

    manifest = {"sha": args.sha, "reports": []}
    failed = False
    for name in names:
        path, gate = REPORTS[name]
        try:
            with open(path) as f:
                report = json.load(f)  # malformed JSON fails here
            headline = gate(report)
            print(f"{path} OK — {headline}")
            manifest["reports"].append(
                {"name": name, "path": path, "ok": True, "headline": headline})
        except (OSError, json.JSONDecodeError, AssertionError, KeyError) as e:
            print(f"{path} FAILED — {e}", file=sys.stderr)
            manifest["reports"].append(
                {"name": name, "path": path, "ok": False, "error": str(e)})
            failed = True

    if args.manifest:
        with open(args.manifest, "w") as f:
            json.dump(manifest, f, indent=2)
            f.write("\n")
        print(f"-> {args.manifest} (sha {args.sha})")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

#!/usr/bin/env bash
# The blocking correctness gate (CI `correctness` job; runnable
# locally): the repo-invariant lint, its self-test, and a curated
# clippy subset that backs lint rule R1 with a real parser.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== xtask lint (R1-R7) =="
cargo run -q -p xtask -- lint

echo "== xtask lint self-test (every rule still fires) =="
cargo run -q -p xtask -- lint --self-test

echo "== xtask unit tests =="
cargo test -q -p xtask

echo "== clippy: curated correctness subset =="
# undocumented_unsafe_blocks re-checks R1 at the AST level;
# dbg_macro/todo are merge hygiene. Deliberately not the whole pedantic
# group — the rest is noise for this codebase (and mutex_atomic
# false-positives on the Gauge/DrainSignal condvar pairs).
for pkg in gpu_bucket_sort xtask; do
  cargo clippy -p "$pkg" --all-targets -- \
    -D warnings \
    -D clippy::undocumented_unsafe_blocks \
    -D clippy::dbg_macro \
    -D clippy::todo
done

echo "correctness: all gates green"

"""L2 pipeline correctness: the full Algorithm-1 JAX pipeline against a
plain sort, across sizes, parameters and value distributions."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def run(x, tile, s):
    return np.asarray(model.bucket_sort(jnp.asarray(x), tile=tile, s=s)[0])


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 8),
    tile=st.sampled_from([64, 256]),
    s=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31),
)
def test_bucket_sort_matches_np(m, tile, s, seed):
    if s > tile:
        return
    rng = np.random.default_rng(seed)
    # Avoid the MAX sentinel (the fixed-shape pipeline's documented
    # keyspace restriction, enforced by the rust runtime).
    x = rng.integers(0, 2**32 - 1, size=m * tile, dtype=np.uint32)
    np.testing.assert_array_equal(run(x, tile, s), np.sort(x))


@pytest.mark.parametrize(
    "pattern",
    ["sorted", "reverse", "moderate_ties", "gaussian"],
)
def test_bucket_sort_patterns(pattern):
    n, tile, s = 4096, 256, 16
    rng = np.random.default_rng(7)
    if pattern == "sorted":
        x = np.sort(rng.integers(0, 2**32 - 1, size=n, dtype=np.uint32))
    elif pattern == "reverse":
        x = np.sort(rng.integers(0, 2**32 - 1, size=n, dtype=np.uint32))[::-1].copy()
    elif pattern == "moderate_ties":
        # Duplicates up to ~n/s multiplicity stay within the bucket
        # capacity guarantee.
        x = rng.integers(0, 64, size=n, dtype=np.uint32) * 1000
    else:
        x = np.clip(
            rng.normal(2**31, 2**28, size=n), 0, 2**32 - 2
        ).astype(np.uint32)
    np.testing.assert_array_equal(run(x, tile, s), np.sort(x))


def test_bucket_sort_aot_ladder_shape():
    # The exact (n, tile, s) combinations aot.py ships.
    from compile.aot import LADDER

    for n, tile, s in LADDER:
        model.validate_shape(n, tile, s)
    # Smallest ladder entry end-to-end.
    n, tile, s = LADDER[0]
    rng = np.random.default_rng(9)
    x = rng.integers(0, 2**32 - 1, size=n, dtype=np.uint32)
    np.testing.assert_array_equal(run(x, tile, s), np.sort(x))


def test_tile_sort_only_variant():
    n, tile = 2048, 256
    rng = np.random.default_rng(11)
    x = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    out = np.asarray(model.tile_sort_only(jnp.asarray(x), tile=tile)[0])
    expect = np.sort(x.reshape(-1, tile), axis=1).reshape(-1)
    np.testing.assert_array_equal(out, expect)


def test_validate_shape_rejects_bad_params():
    with pytest.raises(ValueError):
        model.validate_shape(1000, 256, 16)  # n not a multiple
    with pytest.raises(ValueError):
        model.validate_shape(1024, 100, 10)  # non-pow2
    with pytest.raises(ValueError):
        model.validate_shape(1024, 256, 1)  # s < 2
    with pytest.raises(ValueError):
        model.validate_shape(1024, 64, 128)  # s > tile
    model.validate_shape(1024, 256, 16)


def test_bucket_capacity_guarantee():
    assert model.bucket_capacity(4096, 64) == 128
    assert model.bucket_capacity(4096, 16) == 512
    assert model.bucket_capacity(100, 4) == 64  # next_pow2(50)
    assert model.next_pow2(1) == 1
    assert model.next_pow2(3) == 4

"""L1 kernel correctness: every Pallas kernel against its pure oracle
(ref.py), with hypothesis sweeping shapes, dtypes and value regimes."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic, prefix, rank, ref, scatter

POW2_TILES = [2, 8, 64, 256, 1024]


def keys_array(rng, shape, regime, dtype=np.uint32):
    """Value regimes: full-range, small-alphabet (tie-heavy), constant."""
    if regime == "full":
        return rng.integers(0, 2**32, size=shape, dtype=np.uint32).astype(dtype)
    if regime == "ties":
        return rng.integers(0, 7, size=shape, dtype=np.uint32).astype(dtype)
    return np.full(shape, 42, dtype=dtype)


# ---------------------------------------------------------------- bitonic

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6),
    tile_idx=st.integers(0, len(POW2_TILES) - 1),
    regime=st.sampled_from(["full", "ties", "const"]),
    seed=st.integers(0, 2**31),
)
def test_tile_sort_matches_ref(m, tile_idx, regime, seed):
    rng = np.random.default_rng(seed)
    rows = keys_array(rng, (m, POW2_TILES[tile_idx]), regime)
    out = np.asarray(bitonic.tile_sort(jnp.asarray(rows)))
    np.testing.assert_array_equal(out, ref.tile_sort(rows))


@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
def test_tile_sort_dtypes(dtype):
    rng = np.random.default_rng(1)
    if np.issubdtype(dtype, np.floating):
        rows = rng.standard_normal((3, 128)).astype(dtype)
    else:
        rows = rng.integers(-1000, 1000, size=(3, 128)).astype(dtype)
    out = np.asarray(bitonic.tile_sort(jnp.asarray(rows)))
    np.testing.assert_array_equal(out, np.sort(rows, axis=1))


def test_sort_1d_large():
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2**32, size=8192, dtype=np.uint32)
    out = np.asarray(bitonic.sort_1d(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x))


def test_tile_sort_rejects_bad_rank():
    with pytest.raises(ValueError):
        bitonic.tile_sort(jnp.zeros((2, 2, 2), jnp.uint32))
    with pytest.raises(ValueError):
        bitonic.sort_1d(jnp.zeros((2, 2), jnp.uint32))


def test_tile_sort_rejects_non_pow2():
    with pytest.raises(AssertionError):
        bitonic.tile_sort(jnp.zeros((1, 48), jnp.uint32))


# ------------------------------------------------------------------- rank

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 5),
    tile=st.sampled_from([16, 64, 256]),
    s=st.sampled_from([2, 4, 16]),
    regime=st.sampled_from(["full", "ties"]),
    seed=st.integers(0, 2**31),
)
def test_boundaries_match_ref(m, tile, s, regime, seed):
    rng = np.random.default_rng(seed)
    tiles = np.sort(keys_array(rng, (m, tile), regime), axis=1)
    splitters = np.sort(
        rng.integers(0, 2**32, size=s - 1, dtype=np.uint32)
    )
    out = np.asarray(rank.boundaries(jnp.asarray(tiles), jnp.asarray(splitters)))
    np.testing.assert_array_equal(out, ref.boundaries(tiles, splitters))


def test_boundaries_rejects_empty_splitters():
    with pytest.raises(ValueError):
        rank.boundaries(jnp.zeros((1, 8), jnp.uint32), jnp.zeros((0,), jnp.uint32))


# ----------------------------------------------------------------- prefix

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8),
    s=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_column_prefix_matches_ref(m, s, seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 100, size=(m, s)).astype(np.int32)
    loc, start, size = prefix.column_prefix(jnp.asarray(counts))
    rloc, rstart, rsize = ref.column_prefix(counts)
    np.testing.assert_array_equal(np.asarray(loc), rloc)
    np.testing.assert_array_equal(np.asarray(start), rstart)
    np.testing.assert_array_equal(np.asarray(size), rsize)


def test_column_prefix_layout_tiles_output():
    # The (loc, count) segments must tile [0, total) exactly.
    rng = np.random.default_rng(3)
    m, s = 5, 4
    counts = rng.integers(0, 50, size=(m, s)).astype(np.int32)
    loc, _start, _size = prefix.column_prefix(jnp.asarray(counts))
    segs = sorted(
        (int(np.asarray(loc)[i, j]), int(counts[i, j]))
        for i in range(m)
        for j in range(s)
    )
    expect = 0
    for st_, ln in segs:
        assert st_ == expect
        expect += ln
    assert expect == counts.sum()


# ---------------------------------------------------------------- scatter

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 5),
    tile=st.sampled_from([16, 64, 256]),
    s=st.sampled_from([2, 4, 16]),
    seed=st.integers(0, 2**31),
)
def test_dest_indices_match_ref(m, tile, s, seed):
    rng = np.random.default_rng(seed)
    tiles = np.sort(rng.integers(0, 2**32, size=(m, tile), dtype=np.uint32), axis=1)
    splitters = np.sort(rng.integers(0, 2**32, size=s - 1, dtype=np.uint32))
    bounds = ref.boundaries(tiles, splitters)
    counts = np.diff(bounds, axis=1, prepend=0)
    loc, start, _ = ref.column_prefix(counts)
    cap = 2 * (m * tile) // s + 8
    out = np.asarray(
        scatter.dest_indices(
            jnp.asarray(bounds), jnp.asarray(loc), jnp.asarray(start),
            cap=cap, tile=tile,
        )
    )
    np.testing.assert_array_equal(out, ref.dest_indices(bounds, loc, start, cap))


def test_dest_indices_are_unique_and_in_range():
    rng = np.random.default_rng(4)
    m, tile, s = 4, 64, 8
    tiles = np.sort(rng.integers(0, 2**32, size=(m, tile), dtype=np.uint32), axis=1)
    splitters = np.sort(rng.integers(0, 2**32, size=s - 1, dtype=np.uint32))
    bounds = ref.boundaries(tiles, splitters)
    counts = np.diff(bounds, axis=1, prepend=0)
    loc, start, size = ref.column_prefix(counts)
    cap = 2 * (m * tile) // s
    dest = np.asarray(
        scatter.dest_indices(
            jnp.asarray(bounds), jnp.asarray(loc), jnp.asarray(start),
            cap=cap, tile=tile,
        )
    ).reshape(-1)
    assert len(np.unique(dest)) == m * tile, "destinations must be unique"
    assert dest.min() >= 0
    assert dest.max() < s * cap

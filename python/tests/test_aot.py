"""AOT path checks: HLO-text emission, manifest schema, and a round-trip
through the XLA client exactly as the rust side consumes it."""

import json
import os
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_smoke_build_writes_manifest_and_hlo():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.build(d, ladder=[(4096, 512, 64)], tile_sorts=[])
        assert manifest["version"] == 1
        assert manifest["key_dtype"] == "u32"
        assert len(manifest["entries"]) == 1
        e = manifest["entries"][0]
        assert e["kind"] == "full_sort" and e["n"] == 4096
        path = os.path.join(d, e["file"])
        text = open(path).read()
        # HLO text, not a serialized proto.
        assert text.startswith("HloModule"), text[:40]
        # Schema round-trips through json.
        on_disk = json.load(open(os.path.join(d, "manifest.json")))
        assert on_disk == manifest


def test_hlo_text_has_u32_io():
    text = aot.lower_full_sort(4096, 512, 64)
    # Entry takes u32[4096] and returns a 1-tuple of u32[4096]
    # (layout-annotated in the entry computation signature).
    assert "entry_computation_layout={(u32[4096]{0})->(u32[4096]{0})}" in text


def test_tile_sort_variant_lowers():
    text = aot.lower_tile_sort(4096, 512)
    assert text.startswith("HloModule")
    assert "u32[4096]" in text


def test_lowered_module_executes_like_the_rust_side():
    """Execute the lowered pipeline through jax.jit at the exact ladder
    shape — the same computation the rust PJRT client compiles from the
    HLO text (numerics equivalence of the interchange is covered by the
    rust-side pjrt_roundtrip test)."""
    n, tile, s = aot.LADDER[0]
    rng = np.random.default_rng(5)
    x = rng.integers(0, 2**32 - 1, size=n, dtype=np.uint32)
    out = np.asarray(model.bucket_sort(jnp.asarray(x), tile=tile, s=s)[0])
    np.testing.assert_array_equal(out, np.sort(x))


def test_ladder_is_strictly_increasing_pow2_aligned():
    ns = [n for n, _, _ in aot.LADDER]
    assert ns == sorted(ns)
    for n, tile, s in aot.LADDER:
        assert n % tile == 0
        model.validate_shape(n, tile, s)

"""AOT lowering: JAX/Pallas pipeline → HLO text artifacts + manifest.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example and DESIGN.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``full_sort`` artifact per ladder size plus a ``tile_sort``
variant for the hybrid coordinator path, and ``manifest.json``
(schema consumed by rust/src/runtime/manifest.rs).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n, tile, s) ladder: XLA shapes are static, so the runtime pads each
# request up to the next compiled capacity. Sizes are kept modest —
# interpret-mode Pallas networks unroll O(log² n) vector stages and the
# CPU client executes them eagerly.
LADDER = [
    (4_096, 512, 64),
    (16_384, 512, 64),
    (65_536, 512, 64),
    (262_144, 512, 64),
]

TILE_SORT_SIZES = [(65_536, 512, 64)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_full_sort(n: int, tile: int, s: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.uint32)
    fn = functools.partial(model.bucket_sort, tile=tile, s=s, interpret=True)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_tile_sort(n: int, tile: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.uint32)
    fn = functools.partial(model.tile_sort_only, tile=tile, interpret=True)
    return to_hlo_text(jax.jit(fn).lower(spec))


def build(out_dir: str, ladder=None, tile_sorts=None) -> dict:
    ladder = LADDER if ladder is None else ladder
    tile_sorts = TILE_SORT_SIZES if tile_sorts is None else tile_sorts
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    for n, tile, s in ladder:
        model.validate_shape(n, tile, s)
        name = f"sort_{n}"
        fname = f"{name}.hlo.txt"
        text = lower_full_sort(n, tile, s)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            dict(name=name, kind="full_sort", file=fname, n=n, tile=tile, s=s)
        )
        print(f"wrote {fname} ({len(text) / 1e6:.2f} MB)")

    for n, tile, s in tile_sorts:
        name = f"tile_sort_{n}"
        fname = f"{name}.hlo.txt"
        text = lower_tile_sort(n, tile)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            dict(name=name, kind="tile_sort", file=fname, n=n, tile=tile, s=s)
        )
        print(f"wrote {fname} ({len(text) / 1e6:.2f} MB)")

    manifest = dict(version=1, key_dtype="u32", entries=entries)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote manifest.json ({len(entries)} entries)")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="emit only the smallest artifact (fast CI check)",
    )
    args = parser.parse_args()
    if args.smoke:
        build(args.out, ladder=LADDER[:1], tile_sorts=[])
    else:
        build(args.out)


if __name__ == "__main__":
    main()

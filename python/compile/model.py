"""L2: Algorithm 1 of the paper as one jitted JAX pipeline.

Composes the L1 Pallas kernels into the full deterministic sample sort
over a fixed-shape uint32 array:

    Step 1–2  tile split + per-tile bitonic sort        (kernels.bitonic)
    Step 3    s equidistant samples per tile            (strided gather)
    Step 4    bitonic sort of all s·m samples           (kernels.bitonic)
    Step 5    s−1 equidistant splitters                 (strided gather)
    Step 6    per-tile bucket boundaries                (kernels.rank)
    Step 7    column-major prefix layout                (kernels.prefix)
    Step 8    relocation into the s×cap padded layout   (kernels.scatter
              + one XLA scatter)
    Step 9    per-bucket bitonic sort at capacity       (kernels.bitonic)
    —         compaction gather back to a flat array

`cap = next_pow2(2n/s)` is the paper's deterministic bucket guarantee
(Shi & Schaeffer [15]); `u32::MAX` is the padding sentinel, so the rust
runtime rejects inputs containing it. The whole pipeline is lowered once
by aot.py to HLO text; python never runs at request time.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import bitonic, prefix, rank, scatter

MAX_KEY = jnp.uint32(0xFFFFFFFF)


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (≥ 1)."""
    p = 1
    while p < max(x, 1):
        p *= 2
    return p


def bucket_capacity(n: int, s: int) -> int:
    """The deterministic per-bucket capacity: next_pow2(⌈2n/s⌉)."""
    return next_pow2(-(-2 * n // s))


def validate_shape(n: int, tile: int, s: int) -> None:
    """Static-shape checks shared by the pipeline and aot.py."""
    if n <= 0 or n % tile != 0:
        raise ValueError(f"n={n} must be a positive multiple of tile={tile}")
    if tile & (tile - 1) or s & (s - 1):
        raise ValueError(f"tile={tile} and s={s} must be powers of two")
    if not 2 <= s <= tile or tile % s != 0:
        raise ValueError(f"need 2 <= s <= tile and s | tile (s={s}, tile={tile})")


@functools.partial(jax.jit, static_argnames=("tile", "s", "interpret"))
def bucket_sort(x, *, tile: int, s: int, interpret: bool = True):
    """Sort ``x`` (uint32[n], n a multiple of ``tile``) — Algorithm 1."""
    n = x.shape[0]
    validate_shape(n, tile, s)
    m = n // tile
    cap = bucket_capacity(n, s)

    # Steps 1–2: tile split + local bitonic sort.
    tiles = bitonic.tile_sort(x.reshape(m, tile), interpret=interpret)

    # Step 3: s equidistant samples per tile (position (p+1)·tile/s − 1).
    stride = tile // s
    sample_pos = jnp.arange(1, s + 1) * stride - 1
    samples = tiles[:, sample_pos].reshape(-1)  # (m·s,)

    # Step 4: sort all samples (MAX-padded up to a power of two; the
    # pads sort to the tail, beyond every splitter position).
    padded_samples = next_pow2(m * s)
    if padded_samples != m * s:
        samples = jnp.concatenate(
            [samples, jnp.full((padded_samples - m * s,), MAX_KEY, jnp.uint32)]
        )
    sorted_samples = bitonic.sort_1d(samples, interpret=interpret)

    # Step 5: s−1 equidistant splitters (stride m over m·s samples).
    splitter_pos = jnp.arange(1, s) * m - 1
    splitters = sorted_samples[splitter_pos]

    # Step 6: per-tile bucket boundaries.
    bounds = rank.boundaries(tiles, splitters, interpret=interpret)

    # Step 7: column-major prefix layout.
    counts = bounds - jnp.concatenate(
        [jnp.zeros((m, 1), jnp.int32), bounds[:, :-1]], axis=1
    )
    loc, bucket_start, _bucket_size = prefix.column_prefix(
        counts, interpret=interpret
    )

    # Step 8: relocation into the capacity-padded bucket layout.
    dest = scatter.dest_indices(
        bounds, loc, bucket_start, cap=cap, tile=tile, interpret=interpret
    )
    padded = jnp.full((s * cap,), MAX_KEY, dtype=jnp.uint32)
    padded = padded.at[dest.reshape(-1)].set(tiles.reshape(-1))

    # Step 9: sort every bucket at its guaranteed capacity.
    rows = bitonic.tile_sort(padded.reshape(s, cap), interpret=interpret)

    # Compaction: position t of the result lives in bucket j(t) at
    # offset t − bucket_start[j].
    t_idx = jnp.arange(n)
    j_of_t = (
        jnp.searchsorted(bucket_start, t_idx, side="right").astype(jnp.int32) - 1
    )
    within = t_idx - bucket_start[j_of_t]
    return (rows.reshape(-1)[j_of_t * cap + within],)


def tile_sort_only(x, *, tile: int, interpret: bool = True):
    """Steps 1–2 only (the `tile_sort` artifact variant): returns the
    per-tile-sorted array, same shape."""
    n = x.shape[0]
    if n % tile != 0:
        raise ValueError(f"n={n} must be a multiple of tile={tile}")
    m = n // tile
    return (bitonic.tile_sort(x.reshape(m, tile), interpret=interpret).reshape(n),)

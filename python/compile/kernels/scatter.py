"""Step 8 kernel: relocation destinations.

The paper's Step 8 is a fully coalesced move of each bucket A_ij to its
location l_ij. The fixed-shape XLA pipeline relocates straight into the
*capacity-padded* bucket layout (s rows of ``cap = 2n/s`` keys, the
deterministic guarantee) so Step 9 can sort fixed-size rows: the
destination of the element at position p of sublist i is

    j        = #{boundaries b_i· ≤ p}                (its bucket)
    within   = (loc[i,j] − bucket_start[j]) + (p − b_{i,j−1})
    dest     = j · cap + within

computed per tile in VMEM with a (T × s) broadcast compare (no control
flow); the actual move is then a single XLA scatter at L2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dest_kernel(bounds_ref, loc_ref, start_ref, o_ref, *, cap):
    bounds = bounds_ref[...][0]  # (s,) inclusive prefix boundaries
    loc = loc_ref[...][0]  # (s,)
    start = start_ref[...]  # (s,)
    t = o_ref.shape[1]
    p = jax.lax.iota(jnp.int32, t)
    # Bucket of each position: #{j : bounds[j] <= p}.
    j = jnp.sum(p[:, None] >= bounds[None, :], axis=1, dtype=jnp.int32)
    prev_bound = jnp.where(j > 0, jnp.take(bounds, jnp.maximum(j - 1, 0)), 0)
    within_bucket = jnp.take(loc, j) - jnp.take(start, j) + (p - prev_bound)
    o_ref[...] = (j * cap + within_bucket)[None, :]


@functools.partial(jax.jit, static_argnames=("cap", "tile", "interpret"))
def _dest_impl(bounds, loc, start, cap, tile, interpret=True):
    m, s = bounds.shape
    kernel = functools.partial(_dest_kernel, cap=cap)
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, tile), jnp.int32),
        interpret=interpret,
    )(bounds, loc, start)


def dest_indices(bounds, loc, bucket_start, *, cap, tile, interpret=True):
    """Destination index (into the s×cap padded layout) for every element
    of every sorted sublist. ``bounds``/``loc`` are the (m, s) Step-6/7
    matrices; ``bucket_start`` the (s,) sublist starts."""
    if bounds.shape != loc.shape or bounds.ndim != 2:
        raise ValueError(f"bad shapes {bounds.shape} / {loc.shape}")
    return _dest_impl(
        bounds.astype(jnp.int32),
        loc.astype(jnp.int32),
        bucket_start.astype(jnp.int32),
        cap,
        tile,
        interpret=interpret,
    )

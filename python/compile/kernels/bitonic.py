"""Bitonic sorting-network kernels (Steps 2, 4 and 9 of Algorithm 1).

The paper sorts 2K-item sublists with bitonic sort inside each SM's
shared memory because the network is branch-free and SIMD-perfect (§4).
The same property makes it VPU-perfect: every substage is two gathers, a
min, a max and a select over the whole tile. The network is fully
unrolled at trace time (tile sizes are static), giving
``log²(T)/2 + log(T)/2`` substages of pure vector ops and no
data-dependent control flow at all.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(a, idx, k, j):
    """One substage: compare-exchange pairs ``(i, i^j)`` with direction
    from bit ``k`` of ``i`` — branch-free (two gathers + min/max +
    select)."""
    partner = idx ^ j
    pv = jnp.take(a, partner, axis=0)
    asc = (idx & k) == 0
    lower = (idx & j) == 0
    take_min = lower == asc
    return jnp.where(take_min, jnp.minimum(a, pv), jnp.maximum(a, pv))


def _sort_vector(a):
    """Sort a 1-D power-of-two vector with the full bitonic network."""
    t = a.shape[0]
    if t <= 1:
        return a
    assert t & (t - 1) == 0, f"bitonic needs a power-of-two length, got {t}"
    idx = jax.lax.iota(jnp.int32, t)
    k = 2
    while k <= t:
        j = k // 2
        while j >= 1:
            a = _compare_exchange(a, idx, k, j)
            j //= 2
        k *= 2
    return a


def _tile_sort_kernel(x_ref, o_ref):
    """Sort one (1, T) VMEM-resident tile."""
    o_ref[...] = _sort_vector(x_ref[...][0])[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _tile_sort_impl(rows, interpret=True):
    m, t = rows.shape
    return pl.pallas_call(
        _tile_sort_kernel,
        grid=(m,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t), rows.dtype),
        interpret=interpret,
    )(rows)


def tile_sort(rows, *, interpret=True):
    """Sort every row of ``rows`` (shape (m, T), T a power of two)
    independently — Step 2 (T = tile) and Step 9 (T = bucket capacity).

    One grid step per row: the row streams HBM→VMEM, the whole network
    runs in VMEM, and the sorted row streams back — exactly the paper's
    shared-memory-resident tile sort.
    """
    if rows.ndim != 2:
        raise ValueError(f"tile_sort expects (m, T), got {rows.shape}")
    return _tile_sort_impl(rows, interpret=interpret)


def sort_1d(x, *, interpret=True):
    """Sort a 1-D power-of-two array (Step 4's sample sort)."""
    if x.ndim != 1:
        raise ValueError(f"sort_1d expects a vector, got {x.shape}")
    return tile_sort(x[None, :], interpret=interpret)[0]

"""Pure-jnp/numpy oracles — the correctness ground truth every kernel is
tested against (pytest + hypothesis in python/tests)."""

import numpy as np


def tile_sort(rows):
    """Row-wise sort."""
    return np.sort(np.asarray(rows), axis=1)


def sort_1d(x):
    """Full sort."""
    return np.sort(np.asarray(x))


def boundaries(tiles, splitters):
    """Step-6 boundary matrix via searchsorted."""
    tiles = np.asarray(tiles)
    splitters = np.asarray(splitters)
    m, t = tiles.shape
    s = splitters.shape[0] + 1
    out = np.empty((m, s), dtype=np.int32)
    for i in range(m):
        out[i, : s - 1] = np.searchsorted(tiles[i], splitters, side="left")
        out[i, s - 1] = t
    return out


def column_prefix(counts):
    """Step-7 column-major prefix layout."""
    counts = np.asarray(counts, dtype=np.int64)
    bucket_size = counts.sum(axis=0)
    bucket_start = np.concatenate([[0], np.cumsum(bucket_size)[:-1]])
    col_prefix = np.cumsum(counts, axis=0) - counts
    loc = bucket_start[None, :] + col_prefix
    return (
        loc.astype(np.int32),
        bucket_start.astype(np.int32),
        bucket_size.astype(np.int32),
    )


def dest_indices(bounds, loc, bucket_start, cap):
    """Step-8 destinations into the s×cap padded layout."""
    bounds = np.asarray(bounds)
    loc = np.asarray(loc)
    bucket_start = np.asarray(bucket_start)
    m, s = bounds.shape
    # Tile length is the last (inclusive) boundary.
    t = int(bounds[0, s - 1])
    out = np.empty((m, t), dtype=np.int32)
    for i in range(m):
        p = np.arange(t)
        j = (p[:, None] >= bounds[i][None, :]).sum(axis=1)
        prev = np.where(j > 0, bounds[i][np.maximum(j - 1, 0)], 0)
        within = loc[i][j] - bucket_start[j] + (p - prev)
        out[i] = j * cap + within
    return out


def bucket_sort(x):
    """End-to-end oracle for the full pipeline."""
    return np.sort(np.asarray(x))

"""Step 7 kernel: the column-major prefix sum over bucket sizes
(Figure 1 of the paper).

The paper runs three launches (column sums on all SMs, a prefix over the
s column sums on one SM, a parallel column update). The m×s matrix is a
few MB at most, so on the TPU it fits VMEM whole and the natural form is
one kernel: a column reduction, an exclusive scan of the s sums, and a
per-column exclusive scan — all vector ops.

Outputs: ``loc`` (m, s) — start of bucket A_ij in the relocated array;
``bucket_start`` (s,); ``bucket_size`` (s,).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prefix_kernel(counts_ref, loc_ref, start_ref, size_ref):
    counts = counts_ref[...]  # (m, s) int32
    bucket_size = jnp.sum(counts, axis=0, dtype=jnp.int32)  # (s,)
    csum = jnp.cumsum(bucket_size)
    bucket_start = csum - bucket_size  # exclusive
    col_prefix = jnp.cumsum(counts, axis=0) - counts  # exclusive per column
    loc_ref[...] = bucket_start[None, :] + col_prefix
    start_ref[...] = bucket_start
    size_ref[...] = bucket_size


@functools.partial(jax.jit, static_argnames=("interpret",))
def _column_prefix_impl(counts, interpret=True):
    m, s = counts.shape
    return pl.pallas_call(
        _prefix_kernel,
        in_specs=[pl.BlockSpec((m, s), lambda: (0, 0))],
        out_specs=(
            pl.BlockSpec((m, s), lambda: (0, 0)),
            pl.BlockSpec((s,), lambda: (0,)),
            pl.BlockSpec((s,), lambda: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, s), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ),
        interpret=interpret,
    )(counts)


def column_prefix(counts, *, interpret=True):
    """Column-major prefix layout from the (m, s) bucket-size matrix."""
    if counts.ndim != 2:
        raise ValueError(f"column_prefix expects (m, s), got {counts.shape}")
    return _column_prefix_impl(counts.astype(jnp.int32), interpret=interpret)

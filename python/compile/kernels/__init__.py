"""L1 Pallas kernels for GPU Bucket Sort (build-time only).

Each kernel is the TPU-idiomatic re-expression of one CUDA hot-spot of
the paper (DESIGN.md §Hardware-Adaptation): a thread block working in
16 KB shared memory becomes one grid step over a BlockSpec tile resident
in VMEM; SIMT branch-free compare-exchange becomes vectorized
``jnp.where`` selects on the VPU.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness (against ``ref.py``) is the
build-time gate. Real-TPU performance is estimated structurally in
DESIGN.md.
"""

from . import bitonic, prefix, rank, ref, scatter

__all__ = ["bitonic", "prefix", "rank", "ref", "scatter"]

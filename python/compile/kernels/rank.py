"""Step 6 kernel: Sample Indexing — per-sublist bucket boundaries.

The paper locates each of the s global samples in every sorted sublist
with a thread-doubling parallel binary search, chosen to avoid shared-
memory contention on a GPU (§4). On the VPU there is no contention to
dodge and no divergence to fear, so the idiomatic form is a dense
broadcast-compare: ``boundary[j] = Σ_p tile[p] < splitter[j]`` — one
(T × s−1) comparison block per tile, entirely in VMEM, reduced along T.
Same result, zero control flow (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(tiles_ref, splitters_ref, o_ref):
    tile = tiles_ref[...][0]  # (T,)
    splitters = splitters_ref[...]  # (s-1,)
    t = tile.shape[0]
    counts = jnp.sum(
        tile[:, None] < splitters[None, :], axis=0, dtype=jnp.int32
    )  # (s-1,)
    o_ref[...] = jnp.concatenate(
        [counts, jnp.full((1,), t, jnp.int32)]
    )[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _boundaries_impl(tiles, splitters, interpret=True):
    m, t = tiles.shape
    s = splitters.shape[0] + 1
    return pl.pallas_call(
        _rank_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((s - 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.int32),
        interpret=interpret,
    )(tiles, splitters)


def boundaries(tiles, splitters, *, interpret=True):
    """Boundary matrix b (m, s): ``b[i, j] = |{x ∈ tile_i : x <
    splitter_j}|`` for j < s−1 and ``b[i, s−1] = T``.

    ``tiles`` is (m, T) with every row sorted; ``splitters`` is the
    sorted (s−1,) splitter vector of Step 5.
    """
    if tiles.ndim != 2 or splitters.ndim != 1:
        raise ValueError(f"bad shapes {tiles.shape} / {splitters.shape}")
    if splitters.shape[0] == 0:
        raise ValueError("need at least one splitter (s >= 2)")
    return _boundaries_impl(tiles, splitters, interpret=interpret)

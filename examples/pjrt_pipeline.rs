//! Three-layer pipeline walkthrough: AOT JAX/Pallas artifacts executed
//! from rust via PJRT, cross-checked against the native engine.
//!
//! Demonstrates the full architecture with python nowhere on the
//! request path:
//!   L1 Pallas kernels → L2 JAX pipeline → (build time) HLO text →
//!   L3 rust: HloModuleProto::from_text_file → compile → execute.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_pipeline
//! ```

use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::runtime::PjrtRuntime;
use gpu_bucket_sort::workload::Distribution;
use std::time::Instant;

fn main() {
    let mut rt = match PjrtRuntime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e}\nRun `make artifacts` first.");
            std::process::exit(2);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    println!("artifact manifest:");
    for e in &rt.manifest().entries {
        println!(
            "  {:<18} kind={:<10} n={:<8} tile={} s={} ({})",
            e.name,
            format!("{:?}", e.kind),
            e.n,
            e.tile,
            e.s,
            e.file
        );
    }

    let t0 = Instant::now();
    let compiled = rt.warm_up().expect("artifacts compile");
    println!(
        "\ncompiled {compiled} executables in {:.0} ms\n",
        t0.elapsed().as_secs_f64() * 1e3
    );

    let native = NativeEngine::new(NativeParams::default()).unwrap();
    println!(
        "{:<10} {:>10} {:>14} {:>14}  result",
        "n", "capacity", "pjrt wall", "native wall"
    );
    for n in [1000usize, 4000, 16_000, 60_000, 250_000] {
        let mut keys = Distribution::Uniform.generate(n, n as u64);
        // The fixed-shape pipeline reserves u32::MAX as its padding
        // sentinel.
        for k in keys.iter_mut() {
            if *k == u32::MAX {
                *k -= 1;
            }
        }
        let t = Instant::now();
        let (sorted, cap) = rt.sort(&keys).expect("pjrt sorts");
        let pjrt_ms = t.elapsed().as_secs_f64() * 1e3;

        let mut nkeys = keys.clone();
        let t = Instant::now();
        native.sort(&mut nkeys);
        let native_ms = t.elapsed().as_secs_f64() * 1e3;

        assert_eq!(sorted, nkeys, "engines must agree exactly");
        println!(
            "{:<10} {:>10} {:>11.2} ms {:>11.2} ms  identical ✓",
            n, cap, pjrt_ms, native_ms
        );
    }
    println!("\nAll PJRT results bit-identical to the native engine.");
}

//! Quickstart: the public API in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::workload::Distribution;

fn main() {
    // 1. Generate a workload (the paper's uniform u32 keys).
    let n = 1 << 20;
    let keys = Distribution::Uniform.generate(n, 42);

    // 2. Sort it with GPU Bucket Sort on a simulated GTX 285: the data
    //    work happens for real on the host, and the simulator prices the
    //    exact GPU traffic the algorithm generates.
    let mut simulated = keys.clone();
    let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
    let sorter = BucketSort::new(BucketSortParams::default()); // tile=2048, s=64
    let report = sorter.sort(&mut simulated, &mut sim).expect("fits the device");
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, &simulated));

    println!("GPU Bucket Sort, n = {n} on simulated {}:", sim.spec().name);
    println!(
        "  estimated on-device time : {:.2} ms",
        report.total_estimated_ms(sim.spec())
    );
    println!(
        "  sorting rate             : {:.1} Mkeys/s",
        report.sort_rate_mkeys_s(sim.spec())
    );
    println!("  kernel launches          : {}", report.ledger.kernel_count());
    println!(
        "  peak device memory       : {:.1} MB",
        report.peak_device_bytes as f64 / 1e6
    );
    println!(
        "  largest bucket           : {} (guarantee ≤ {})",
        report.max_bucket,
        2 * report.padded_n / report.s
    );
    for (step, ms) in report.step_ms(sim.spec()) {
        println!("  step {step}: {ms:.2} ms");
    }

    // 3. The same algorithm as a real multicore sort (the service's
    //    production engine).
    let engine = NativeEngine::new(NativeParams::default()).unwrap();
    let mut native = keys.clone();
    let nr = engine.sort(&mut native);
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, &native));
    println!(
        "\nNative engine ({} workers): {:.2} ms wall = {:.1} Mkeys/s",
        engine.workers(),
        nr.wall_ms,
        nr.rate_mkeys_s()
    );
}

//! Distribution-robustness study — the §5 determinism claim, executed
//! (not analytic): GPU Bucket Sort's launch/traffic profile is
//! input-independent, while randomized sample sort [9] fluctuates with
//! the input distribution.
//!
//! ```bash
//! cargo run --release --example robustness [-- n_keys]
//! ```

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::algos::randomized::{RandomizedParams, RandomizedSampleSort};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::workload::Distribution;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(1 << 20);
    let gpu = GpuModel::Gtx285_2G;
    let spec = gpu.spec();
    let gbs = BucketSort::new(BucketSortParams::default());
    let rss = RandomizedSampleSort::new(RandomizedParams {
        base_case: 1 << 14,
        ..RandomizedParams::default()
    });

    println!(
        "n = {n} keys on simulated {} — estimated ms per input distribution\n",
        spec.name
    );
    println!(
        "{:<16} {:>14} {:>14} {:>12} {:>10}",
        "distribution", "deterministic", "randomized", "rss skew", "rss depth"
    );
    let mut gbs_ms = Vec::new();
    let mut rss_ms = Vec::new();
    for dist in Distribution::ROBUSTNESS_SUITE {
        let keys = dist.generate(n, 7);

        let mut sim = GpuSim::new(gpu.spec());
        let g = gbs.sort(&mut keys.clone(), &mut sim).expect("gbs sorts");
        let g_ms = g.total_estimated_ms(&spec);

        let mut sim2 = GpuSim::new(gpu.spec());
        let r = rss.sort(&mut keys.clone(), &mut sim2).expect("rss sorts");
        let r_ms = r.total_estimated_ms(&spec);

        println!(
            "{:<16} {:>11.2} ms {:>11.2} ms {:>11.2}x {:>10}",
            dist.id(),
            g_ms,
            r_ms,
            r.worst_bucket_skew,
            r.max_depth
        );
        gbs_ms.push((dist, g_ms));
        rss_ms.push(r_ms);
    }

    let spread = |v: &[f64]| {
        let max = v.iter().copied().fold(0.0f64, f64::max);
        let min = v.iter().copied().fold(f64::INFINITY, f64::min);
        max / min - 1.0
    };
    let g_all: Vec<f64> = gbs_ms.iter().map(|(_, v)| *v).collect();
    let g_tie_bounded: Vec<f64> = gbs_ms
        .iter()
        .filter(|(d, _)| d.id() != "zipf")
        .map(|(_, v)| *v)
        .collect();

    println!("\nspread (max/min − 1):");
    println!("  deterministic, tie-bounded inputs : {:.6}  (the paper's <1 ms variance)", spread(&g_tie_bounded));
    println!("  deterministic, incl. zipf         : {:.4}  (unbounded ties exceed the 2n/s guarantee — see DESIGN.md §Limitations)", spread(&g_all));
    println!("  randomized [9]                    : {:.4}  (the fluctuation the paper eliminates)", spread(&rss_ms));
}

//! Regenerate every table and figure of the paper's evaluation section
//! into `results/*.csv` (plus console markdown) — the one-shot
//! reproduction driver.
//!
//! ```bash
//! cargo run --release --example paper_figures [-- fast]
//! ```

use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::GpuModel;
use std::path::Path;

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let max_n = if fast { 32 << 20 } else { 512 << 20 };
    let out = Path::new("results");

    let ladder = exp::paper_n_ladder(max_n);
    let ladder_256 = exp::paper_n_ladder(max_n.min(256 << 20));
    let fig3_ns: Vec<usize> = if fast {
        vec![32 << 20]
    } else {
        exp::FIG3_NS.to_vec()
    };

    let mut tables = vec![
        exp::table1(),
        exp::fig3_sample_size(&fig3_ns, &exp::FIG3_S_VALUES),
        exp::fig4_devices(&ladder),
        exp::fig5_step_breakdown(&ladder_256),
        exp::fig6_gtx285(&ladder_256),
        exp::fig7_tesla(&ladder),
        exp::sort_rate_series(&ladder, GpuModel::TeslaC1060),
    ];
    let (rob, gbs_spread, rss_spread) = exp::robustness(if fast { 1 << 17 } else { 1 << 20 }, 7);
    tables.push(rob);

    for t in &tables {
        println!("{}", t.to_markdown());
        let path = t.write_csv(out).expect("write csv");
        println!("→ {}\n", path.display());
    }
    println!(
        "robustness spread (max/min − 1): deterministic {gbs_spread:.4}, randomized {rss_spread:.4}"
    );
    println!("\nAll figures regenerated under {}/", out.display());
}

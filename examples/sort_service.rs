//! End-to-end service driver — the full-system validation run recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//! Starts the batched sort service on the native multicore engine,
//! drives it with a realistic mixed workload (concurrent tenants,
//! mixed request sizes and distributions, bursts), and reports
//! latency percentiles, batching behaviour and aggregate throughput.
//! If AOT artifacts are present, the same workload (size-capped) is
//! then replayed against the PJRT engine, proving all three layers
//! compose: Pallas kernels → JAX pipeline → HLO text → rust runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example sort_service
//! ```

use gpu_bucket_sort::config::{BatchConfig, EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortJob, SortRequest, SortService};
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::KeyType;
use std::time::Instant;

fn main() {
    let cfg = ServiceConfig {
        verify: true, // every response checked: sorted permutation
        batch: BatchConfig {
            max_wait_ms: 2,
            ..BatchConfig::default()
        },
        ..ServiceConfig::default()
    };
    println!("=== native engine under mixed load ===");
    run_load(cfg.clone(), 96, 8, &[16 << 10, 128 << 10, 1 << 20]);

    // The typed surface: one request per key type, plus a key–value
    // job whose payloads must come back married to their keys.
    println!("\n=== typed requests (SortKey surface) ===");
    let client = SortService::start(cfg).expect("service starts");
    for kt in KeyType::ALL {
        let keys = Distribution::Uniform.generate_data(kt, 64 << 10, 7);
        let t = Instant::now();
        let resp = client
            .sort(SortRequest::builder(keys).self_check(true).build().unwrap())
            .expect("typed request succeeds");
        println!(
            "  {kt}: {} keys sorted + self-checked in {:.1} ms",
            resp.keys.len(),
            t.elapsed().as_secs_f64() * 1e3
        );
    }
    let keys: Vec<u32> = Distribution::Zipf.generate(64 << 10, 9);
    let payload: Vec<u64> = (0..keys.len() as u64).collect();
    let resp = client
        .sort(
            SortRequest::builder(keys.clone())
                .payload(payload)
                .descending(true)
                .self_check(true)
                .build()
                .unwrap(),
        )
        .expect("key–value request succeeds");
    let sorted = resp.keys_u32();
    for (k, p) in sorted.iter().zip(resp.payload.as_ref().unwrap()) {
        assert_eq!(keys[*p as usize], *k, "payload stayed with its key");
    }
    println!(
        "  u32 key–value, descending: {} records, payload pairing verified",
        sorted.len()
    );
    client.shutdown();

    // PJRT replay (sizes capped by the compiled artifact ladder).
    let pjrt_cfg = ServiceConfig {
        engine: EngineKind::Pjrt,
        verify: true,
        ..ServiceConfig::default()
    };
    match SortService::start(pjrt_cfg.clone()) {
        Ok(client) => {
            client.shutdown();
            println!("\n=== PJRT (AOT JAX/Pallas) engine, same workload shape ===");
            run_load(pjrt_cfg, 24, 4, &[4 << 10, 16 << 10, 64 << 10]);
        }
        Err(e) => println!("\n(PJRT replay skipped: {e})"),
    }
}

fn run_load(cfg: ServiceConfig, requests: usize, tenants: usize, sizes: &[usize]) {
    let client = SortService::start(cfg).expect("service starts");
    let dists = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::Staggered,
        Distribution::NearlySorted,
    ];
    let t0 = Instant::now();
    let latencies = std::sync::Mutex::new(Vec::<f64>::new());
    let mut total_keys = 0usize;
    std::thread::scope(|scope| {
        for tenant in 0..tenants {
            let client = client.clone();
            let latencies = &latencies;
            let per_tenant = requests / tenants;
            scope.spawn(move || {
                for r in 0..per_tenant {
                    let n = sizes[(tenant + r) % sizes.len()];
                    let dist = dists[(tenant * 7 + r) % dists.len()];
                    let keys = dist.generate(n, (tenant * 1000 + r) as u64);
                    let t = Instant::now();
                    let out = client
                        .sort(SortJob::tagged(keys, format!("tenant-{tenant}")))
                        .expect("request succeeds");
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    assert_eq!(out.tag.as_deref(), Some(format!("tenant-{tenant}").as_str()));
                    latencies.lock().unwrap().push(ms);
                }
            });
        }
        for &n in sizes {
            total_keys += n;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let _ = total_keys;

    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| lat[((q * lat.len() as f64) as usize).min(lat.len() - 1)];
    let snap = client.shutdown();
    let keys_sorted = snap.counters.get("keys_sorted").copied().unwrap_or(0);
    let batches = snap.counters.get("batches_dispatched").copied().unwrap_or(0);
    let reqs = snap.counters.get("requests_completed").copied().unwrap_or(0);

    println!(
        "{reqs} requests / {batches} batches ({:.2} req/batch) in {wall:.2}s",
        reqs as f64 / batches.max(1) as f64
    );
    println!(
        "throughput: {:.1} Mkeys/s aggregate",
        keys_sorted as f64 / wall / 1e6
    );
    println!(
        "latency: p50 {:.1} ms | p90 {:.1} ms | p99 {:.1} ms | max {:.1} ms",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lat.last().unwrap()
    );
    println!("--- service metrics ---\n{}", snap.summary());
}

//! Repo task runner. One subcommand today:
//!
//! ```text
//! cargo run -p xtask -- lint            # scan rust/src against R1–R7
//! cargo run -p xtask -- lint --self-test # prove every rule still fires
//! ```
//!
//! The lint is the blocking CI gate for the repo's concurrency and
//! panic-safety invariants (`ci/correctness.sh` runs it). Seven rules,
//! scanned with a hand-rolled comment/string-stripping tokenizer (the
//! build is dependency-free, so no `syn`):
//!
//! * **R1 — documented unsafe.** Every `unsafe` block, fn or impl in
//!   `rust/src/` carries a `// SAFETY:` comment directly above it.
//! * **R2 — no ad-hoc threads.** `std::thread::spawn` /
//!   `std::thread::Builder` appear only in the sync facade
//!   (`util/sync.rs`) and the model checker (`util/loom.rs`); everything
//!   else goes through `util::sync::thread::spawn_named` or the worker
//!   pool, so `--cfg loom` models see every thread.
//! * **R3 — facade-only primitives.** The loom-modeled modules (pool,
//!   arena, bounded queue, scheduler, net server/client/credit) never
//!   name `std::sync::{Mutex, Condvar, atomic, …}` directly — they
//!   would silently escape the model under `--cfg loom`. (`mpsc`,
//!   `OnceLock` and the poison types are fine: the model does not
//!   mirror them.)
//! * **R4 — deterministic algorithms.** No `Instant::now` /
//!   `SystemTime` in `rust/src/algos/`: kernel code must stay replayable
//!   and benchmark-neutral; timing belongs to the exec/bench layers.
//! * **R5 — no panicking service paths.** No `.unwrap()` / `.expect(`
//!   in non-test `rust/src/net/` or `coordinator/service.rs`: a
//!   malformed frame or dead peer must become a typed error, never a
//!   panicked reader/pump thread with poisoned locks behind it.
//! * **R6 — bounded backoff only.** No `thread::sleep` outside
//!   `util/backoff.rs`: ad-hoc sleep-retry loops hide unbounded waits
//!   and drift; retries route through `util::backoff::sleep_backoff`
//!   so every wait is capped, attempt-indexed and greppable.
//! * **R7 — cluster tier on the facade and backoff.** The cluster
//!   modules (`net/registry.rs`, `net/cluster.rs`) must import both
//!   `util::sync` and `util::backoff`: heartbeat pacing, drain
//!   signalling and failover retries all live there, and a module
//!   that bypasses the facade (or open-codes its retry waits) would
//!   silently escape the loom models and the R6 bound. They are also
//!   FACADE_COVERED, so R3 polices the primitives themselves.
//!
//! Test regions (`#[cfg(test)]` / `#[cfg(all(test, …))]` items) are
//! exempt from R2/R3/R5/R6. Deliberate exceptions go in
//! `ci/lint_allow.txt` as `<RULE> <path>` lines.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") if args.iter().any(|a| a == "--self-test") => match self_test() {
            Ok(n) => {
                println!("xtask lint self-test: all {n} rules fire and stay quiet on clean code");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("xtask lint self-test FAILED: {e}");
                ExitCode::FAILURE
            }
        },
        Some("lint") => run_lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--self-test]");
            ExitCode::FAILURE
        }
    }
}

fn run_lint() -> ExitCode {
    let root = repo_root();
    let files = collect_sources(&root.join("rust").join("src"));
    if files.is_empty() {
        eprintln!("xtask lint: no sources under rust/src — wrong working directory?");
        return ExitCode::FAILURE;
    }
    let allow = load_allowlist(&root.join("ci").join("lint_allow.txt"));
    let mut violations = Vec::new();
    for (rel, text) in &files {
        violations.extend(scan_file(rel, text));
    }
    violations.retain(|v| !allow.iter().any(|(r, p)| r == v.rule && p == &v.path));
    if violations.is_empty() {
        println!("xtask lint: {} files clean (R1–R7)", files.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!(
            "xtask lint: {} violation(s). Fix them or, for a deliberate exception, \
             add `<RULE> <path>` to ci/lint_allow.txt with a comment saying why.",
            violations.len()
        );
        ExitCode::FAILURE
    }
}

/// Walk up from the current directory to the directory containing
/// `rust/src` (cargo runs xtask from the workspace root, but be
/// forgiving about being invoked from a subdirectory).
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().expect("current dir");
    loop {
        if dir.join("rust").join("src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().expect("current dir");
        }
    }
}

/// All `.rs` files under `dir`, as (repo-relative path, content),
/// sorted for deterministic output. Paths use `/` separators.
fn collect_sources(dir: &Path) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else { continue };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                if let Ok(text) = std::fs::read_to_string(&path) {
                    let rel = path
                        .strip_prefix(dir.parent().and_then(Path::parent).unwrap_or(dir))
                        .unwrap_or(&path)
                        .components()
                        .map(|c| c.as_os_str().to_string_lossy().into_owned())
                        .collect::<Vec<_>>()
                        .join("/");
                    out.push((rel, text));
                }
            }
        }
    }
    out.sort();
    out
}

/// `<RULE> <path>` lines; `#` starts a comment.
fn load_allowlist(path: &Path) -> Vec<(String, String)> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    text.lines()
        .map(|l| l.split('#').next().unwrap_or("").trim())
        .filter(|l| !l.is_empty())
        .filter_map(|l| {
            let mut it = l.split_whitespace();
            Some((it.next()?.to_string(), it.next()?.to_string()))
        })
        .collect()
}

#[derive(Debug, PartialEq, Eq)]
struct Violation {
    rule: &'static str,
    path: String,
    line: usize, // 1-based
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{}: {}", self.rule, self.path, self.line, self.msg)
    }
}

/// Modules whose sync primitives must come from the facade (R3): these
/// are the ones `rust/tests/loom_models.rs` compiles into interleaving
/// models under `--cfg loom`.
const FACADE_COVERED: &[&str] = &[
    "src/util/pool.rs",
    "src/util/arena.rs",
    "src/coordinator/queue.rs",
    "src/coordinator/scheduler.rs",
    "src/net/server.rs",
    "src/net/client.rs",
    "src/net/credit.rs",
    "src/net/registry.rs",
    "src/net/cluster.rs",
];

/// Modules that must route every wait and wakeup through the shared
/// helpers (R7): the cluster tier's heartbeat/failover machinery.
const CLUSTER_TIER: &[&str] = &["src/net/registry.rs", "src/net/cluster.rs"];

/// Files allowed to spawn raw OS threads (R2): the facade itself and
/// the model checker it swaps in.
const SPAWN_ALLOWED: &[&str] = &["src/util/sync.rs", "src/util/loom.rs"];

/// `std::sync::` suffixes the facade deliberately does not mirror.
const STD_SYNC_OK: &[&str] = &["mpsc", "OnceLock", "LockResult", "PoisonError", "TryLockError"];

fn scan_file(rel: &str, text: &str) -> Vec<Violation> {
    let raw: Vec<&str> = text.lines().collect();
    let stripped = strip_comments_and_strings(text);
    let code: Vec<&str> = stripped.lines().collect();
    let in_test = test_region_mask(&code);
    let mut out = Vec::new();

    let suffix_matches = |s: &str| rel.ends_with(s);
    let covered = FACADE_COVERED.iter().any(|s| suffix_matches(s));
    let spawn_ok = SPAWN_ALLOWED.iter().any(|s| suffix_matches(s));
    let in_algos = rel.contains("src/algos/");
    let no_panic = rel.contains("src/net/") || rel.ends_with("src/coordinator/service.rs");
    let sleep_ok = suffix_matches("src/util/backoff.rs");

    // R7: the cluster-tier modules must go through the shared wait
    // helpers. A whole-file presence check (reported at line 1): the
    // heartbeat loop and failover retries cannot be written correctly
    // without naming both helper modules, so their absence means the
    // module grew its own pacing.
    if CLUSTER_TIER.iter().any(|s| suffix_matches(s)) {
        for (needle, fix) in [
            ("util::sync", "pace waits through the crate::util::sync facade"),
            ("util::backoff", "pace retries through util::backoff::sleep_backoff"),
        ] {
            if !stripped.contains(needle) {
                out.push(Violation {
                    rule: "R7",
                    path: rel.to_string(),
                    line: 1,
                    msg: format!("cluster-tier module never names `{needle}` — {fix}"),
                });
            }
        }
    }

    for (i, line) in code.iter().enumerate() {
        let lineno = i + 1;
        let test = in_test[i];

        // R1: every `unsafe` keyword is preceded by a contiguous
        // comment block containing `SAFETY:`. Applies everywhere,
        // tests included.
        if contains_word(line, "unsafe") && !has_safety_comment(&raw, i) {
            out.push(Violation {
                rule: "R1",
                path: rel.to_string(),
                line: lineno,
                msg: "`unsafe` without a `// SAFETY:` comment directly above".into(),
            });
        }

        // R2: raw thread spawning outside the facade/model checker.
        if !test
            && !spawn_ok
            && (line.contains("std::thread::spawn") || line.contains("std::thread::Builder"))
        {
            out.push(Violation {
                rule: "R2",
                path: rel.to_string(),
                line: lineno,
                msg: "raw std::thread spawn — use util::sync::thread::spawn_named \
                      (or the worker pool) so `--cfg loom` models see this thread"
                    .into(),
            });
        }

        // R3: facade-covered modules naming std primitives directly.
        if !test && covered {
            for bad in std_sync_escapes(line) {
                out.push(Violation {
                    rule: "R3",
                    path: rel.to_string(),
                    line: lineno,
                    msg: format!(
                        "`std::sync::{bad}` in a loom-modeled module — import it \
                         from crate::util::sync so `--cfg loom` can mirror it"
                    ),
                });
            }
        }

        // R4: wall-clock reads inside algorithm kernels.
        if in_algos && !test && (line.contains("Instant::now") || line.contains("SystemTime")) {
            out.push(Violation {
                rule: "R4",
                path: rel.to_string(),
                line: lineno,
                msg: "wall-clock read in algos/ — kernels must stay deterministic; \
                      time belongs to the exec/bench layers"
                    .into(),
            });
        }

        // R5: panicking calls on the wire / service intake paths.
        if no_panic && !test && (line.contains(".unwrap()") || line.contains(".expect(")) {
            out.push(Violation {
                rule: "R5",
                path: rel.to_string(),
                line: lineno,
                msg: "`.unwrap()`/`.expect(` on a service path — return a typed \
                      error; a panic here poisons connection locks"
                    .into(),
            });
        }

        // R6: raw sleeps outside the backoff helper.
        if !test && !sleep_ok && line.contains("thread::sleep") {
            out.push(Violation {
                rule: "R6",
                path: rel.to_string(),
                line: lineno,
                msg: "raw `thread::sleep` — route the wait through \
                      util::backoff::sleep_backoff so it stays capped and \
                      attempt-indexed"
                    .into(),
            });
        }
    }
    out
}

/// True if `word` occurs in `line` delimited by non-identifier chars
/// (so `unsafe_code` or `forbid(unsafe_code)` never match `unsafe`).
fn contains_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_before = start == 0 || !is_ident(bytes[start - 1]);
        let ok_after = end >= bytes.len() || !is_ident(bytes[end]);
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

/// The contiguous run of `//…` lines directly above `raw[i]` (blank
/// lines stop the search; attribute lines `#[…]` are skipped so
/// `// SAFETY:` may sit above an `#[allow]`). True if any of them
/// contains `SAFETY:`.
fn has_safety_comment(raw: &[&str], i: usize) -> bool {
    // Same-line trailing comment also counts (`unsafe { … } // SAFETY: …`
    // is unusual but unambiguous).
    if raw[i].contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.starts_with("//") {
            if t.contains("SAFETY:") {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#![") {
            continue;
        } else {
            return false;
        }
    }
    false
}

/// `std::sync::<segment>` occurrences in a stripped line whose first
/// path segment after `std::sync::` is not on the facade's OK-list.
/// A brace import (`use std::sync::{…}`) is reported as `{…}`.
fn std_sync_escapes(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let needle = "std::sync::";
    let mut from = 0;
    while let Some(pos) = line[from..].find(needle) {
        let rest = &line[from + pos + needle.len()..];
        if rest.starts_with('{') {
            out.push("{…}".to_string());
        } else {
            let seg: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !seg.is_empty() && !STD_SYNC_OK.contains(&seg.as_str()) {
                out.push(seg);
            }
        }
        from += pos + needle.len();
    }
    out
}

/// Blank out line comments, block comments and string/char literals,
/// preserving line structure and column positions (replaced by
/// spaces), so token scans never match inside them.
fn strip_comments_and_strings(text: &str) -> String {
    #[derive(PartialEq)]
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let b: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut st = St::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        let next = b.get(i + 1).copied();
        match st {
            St::Code => match c {
                '/' if next == Some('/') => {
                    st = St::Line;
                    out.push(' ');
                }
                '/' if next == Some('*') => {
                    st = St::Block(1);
                    out.push(' ');
                }
                '"' => {
                    st = St::Str;
                    out.push(' ');
                }
                'r' if matches!(next, Some('"') | Some('#'))
                    && !prev_is_ident(&b, i) =>
                {
                    // Raw string: count the hashes after `r`.
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                    out.push(c);
                }
                '\'' => {
                    // Char literal vs lifetime tick: a literal is either
                    // escaped (`'\n'`, `'\u{…}'`) or exactly one char
                    // wide (`'x'`); anything else is a lifetime.
                    let is_literal = next == Some('\\')
                        || (b.get(i + 2) == Some(&'\'') && next != Some('\''));
                    if is_literal {
                        let mut j = i + 1;
                        while j < b.len() && b[j] != '\'' {
                            j += if b[j] == '\\' { 2 } else { 1 };
                        }
                        let j = j.min(b.len() - 1);
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                    out.push(c); // lifetime tick
                }
                _ => out.push(c),
            },
            St::Line => {
                if c == '\n' {
                    st = St::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
            }
            St::Block(depth) => {
                if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                out.push(if c == '\n' { '\n' } else { ' ' });
            }
            St::Str => match c {
                '\\' => {
                    // Keep the newline of a line-continuation escape so
                    // line numbers stay aligned.
                    out.push(' ');
                    if next == Some('\n') {
                        out.push('\n');
                    } else {
                        out.push(' ');
                    }
                    i += 2;
                    continue;
                }
                '"' => {
                    st = St::Code;
                    out.push(' ');
                }
                '\n' => out.push('\n'),
                _ => out.push(' '),
            },
            St::RawStr(hashes) => {
                if c == '"' {
                    let done = (1..=hashes as usize)
                        .all(|k| b.get(i + k) == Some(&'#'));
                    if done {
                        st = St::Code;
                        for _ in 0..=hashes as usize {
                            out.push(' ');
                        }
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                out.push(if c == '\n' { '\n' } else { ' ' });
            }
        }
        i += 1;
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == '_')
}

/// Per-line mask: true where the line belongs to a `#[cfg(test)]` /
/// `#[cfg(all(test, …))]` item, tracked by brace counting on the
/// stripped source from the attribute's following `{`.
fn test_region_mask(code: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        let t = code[i].trim_start();
        if t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test") {
            // The guarded item runs from here to the close of the first
            // brace block that opens at or after the attribute.
            let mut depth = 0i32;
            let mut opened = false;
            let mut j = i;
            'outer: while j < code.len() {
                mask[j] = true;
                for ch in code[j].chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => {
                            depth -= 1;
                            if opened && depth == 0 {
                                break 'outer;
                            }
                        }
                        // An unbraced guarded item (`#[cfg(test)] use …;`)
                        // ends at the first `;` before any brace opens.
                        ';' if !opened => break 'outer,
                        _ => {}
                    }
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

// ---------------------------------------------------------------------
// Self-test: one seeded violation per rule, plus clean twins, so CI can
// prove the scanner still fires before trusting a green lint.
// ---------------------------------------------------------------------

fn self_test() -> Result<usize, String> {
    struct Case {
        name: &'static str,
        path: &'static str,
        src: &'static str,
        expect_rule: Option<&'static str>,
    }
    let cases = [
        Case {
            name: "R1 fires on undocumented unsafe",
            path: "src/algos/seeded.rs",
            src: "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
            expect_rule: Some("R1"),
        },
        Case {
            name: "R1 quiet with SAFETY comment",
            path: "src/algos/seeded.rs",
            src: "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller contract.\n    unsafe { *p }\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R2 fires on raw spawn",
            path: "src/coordinator/seeded.rs",
            src: "pub fn f() {\n    std::thread::spawn(|| {});\n}\n",
            expect_rule: Some("R2"),
        },
        Case {
            name: "R2 quiet in tests and in the facade",
            path: "src/util/sync.rs",
            src: "pub fn f() {\n    std::thread::spawn(|| {});\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R3 fires on std Mutex in a covered module",
            path: "src/util/pool.rs",
            src: "pub fn f() -> std::sync::Mutex<u32> {\n    std::sync::Mutex::new(0)\n}\n",
            expect_rule: Some("R3"),
        },
        Case {
            name: "R3 quiet for mpsc and in test regions",
            path: "src/util/pool.rs",
            src: "use std::sync::mpsc;\n#[cfg(test)]\nmod tests {\n    use std::sync::Mutex;\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R4 fires on Instant::now in algos",
            path: "src/algos/seeded.rs",
            src: "pub fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
            expect_rule: Some("R4"),
        },
        Case {
            name: "R4 quiet outside algos",
            path: "src/exec/seeded.rs",
            src: "pub fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R5 fires on unwrap in net",
            path: "src/net/seeded.rs",
            src: "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
            expect_rule: Some("R5"),
        },
        Case {
            name: "R5 quiet for unwrap_or_else and in comments/strings",
            path: "src/net/seeded.rs",
            src: "pub fn f(x: Option<u32>) -> &'static str {\n    // .unwrap() in a comment\n    let _ = x.unwrap_or_else(|| 0);\n    \".unwrap()\"\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R6 fires on a raw sleep-retry",
            path: "src/net/seeded.rs",
            src: "pub fn f() {\n    std::thread::sleep(std::time::Duration::from_millis(5));\n}\n",
            expect_rule: Some("R6"),
        },
        Case {
            name: "R6 quiet in the backoff helper and in tests",
            path: "src/util/backoff.rs",
            src: "pub fn f() {\n    std::thread::sleep(std::time::Duration::from_millis(5));\n}\n#[cfg(test)]\nmod tests {\n    fn g() {\n        std::thread::sleep(std::time::Duration::from_millis(5));\n    }\n}\n",
            expect_rule: None,
        },
        Case {
            name: "R7 fires on a cluster module bypassing the helpers",
            path: "src/net/cluster.rs",
            src: "pub fn f() -> u32 {\n    41 + 1\n}\n",
            expect_rule: Some("R7"),
        },
        Case {
            name: "R7 quiet when both helper modules are imported",
            path: "src/net/cluster.rs",
            src: "use crate::util::backoff::{sleep_backoff, Backoff};\nuse crate::util::sync::lock_unpoisoned;\npub fn f() -> u32 {\n    41 + 1\n}\n",
            expect_rule: None,
        },
    ];
    let mut fired = std::collections::BTreeSet::new();
    for c in &cases {
        let found = scan_file(c.path, c.src);
        match c.expect_rule {
            Some(rule) => {
                if !found.iter().any(|v| v.rule == rule) {
                    return Err(format!(
                        "{}: expected {rule} to fire, got {found:?}",
                        c.name
                    ));
                }
                fired.insert(rule);
            }
            None => {
                if !found.is_empty() {
                    return Err(format!("{}: expected clean, got {found:?}", c.name));
                }
            }
        }
    }
    if fired.len() != 7 {
        return Err(format!("only {:?} fired — expected all seven rules", fired));
    }
    Ok(fired.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_rule_fires_and_clean_twins_pass() {
        assert_eq!(self_test().expect("self-test"), 7);
    }

    #[test]
    fn stripper_preserves_lines_and_blanks_literals() {
        let src = "let a = \"un//safe\"; // unsafe\nlet b = 'x';\n";
        let s = strip_comments_and_strings(src);
        assert_eq!(s.lines().count(), src.lines().count());
        assert!(!s.contains("un//safe"));
        assert!(!s.contains("unsafe"));
        assert!(s.contains("let a ="));
        assert!(s.contains("let b ="));
    }

    #[test]
    fn raw_strings_and_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet r = r#\"unsafe { } \"#;\n";
        let s = strip_comments_and_strings(src);
        assert!(s.contains("fn f<'a>(x: &'a str)"));
        assert!(!s.contains("unsafe"));
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(contains_word("unsafe {", "unsafe"));
        assert!(contains_word("unsafe impl Send for T {}", "unsafe"));
        assert!(!contains_word("#![forbid(unsafe_code)]", "unsafe"));
        assert!(!contains_word("let not_unsafe = 1;", "unsafe"));
    }

    #[test]
    fn test_region_mask_tracks_braces() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let stripped = strip_comments_and_strings(src);
        let code: Vec<&str> = stripped.lines().collect();
        let mask = test_region_mask(&code);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn safety_comment_may_sit_above_attributes() {
        let src = "// SAFETY: fine.\n#[allow(clippy::transmute_int_to_float)]\nconst X: f32 = unsafe { std::mem::transmute::<u32, f32>(1) };\n";
        assert!(scan_file("src/key.rs", src).is_empty());
    }

    #[test]
    fn brace_import_of_std_sync_is_flagged_in_covered_modules() {
        let src = "use std::sync::{Arc, Mutex};\n";
        let v = scan_file("src/net/server.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "R3");
    }

    #[test]
    fn allowlist_format_parses() {
        // (Parsed from a string through the same splitter the loader
        // uses; the loader itself just adds file IO.)
        let text = "# comment\nR5 rust/src/net/legacy.rs # why: …\n\n";
        let parsed: Vec<(String, String)> = text
            .lines()
            .map(|l| l.split('#').next().unwrap_or("").trim())
            .filter(|l| !l.is_empty())
            .filter_map(|l| {
                let mut it = l.split_whitespace();
                Some((it.next()?.to_string(), it.next()?.to_string()))
            })
            .collect();
        assert_eq!(parsed, vec![("R5".to_string(), "rust/src/net/legacy.rs".to_string())]);
    }
}
